"""Flash-attention backward: interpret-mode grad parity vs jax.grad of the
materialized-softmax reference, across causal/non-causal, GQA group sizes,
padded sequence lengths, and per-batch valid-length masks."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention.ops import flash_mha


def rnd(shape, dtype=jnp.float32, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape).astype(dtype)


def ref_mha(q, k, v, causal, kv_valid_len=None):
    """Materialized-scores oracle in the (B, S, H, D) layout."""
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    mask = jnp.ones((b, 1, sq, skv), bool)
    if causal:
        mask = mask & (jnp.arange(sq)[:, None] >= jnp.arange(skv)[None, :])
    if kv_valid_len is not None:
        mask = mask & (jnp.arange(skv)[None, None, None, :]
                       < kv_valid_len[:, None, None, None])
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def grad_pair(q, k, v, causal, kv_valid_len=None, block=32):
    w = rnd(jax.eval_shape(
        lambda: ref_mha(q, k, v, causal, kv_valid_len)).shape, seed=9)

    def loss_kernel(q, k, v):
        o = flash_mha(q, k, v, causal=causal, kv_valid_len=kv_valid_len,
                      block_q=block, block_k=block, interpret=True)
        return jnp.sum(o * w)

    def loss_ref(q, k, v):
        return jnp.sum(ref_mha(q, k, v, causal, kv_valid_len) * w)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    return gk, gr


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("b,h,hkv,sq,skv,d", [
    (1, 2, 2, 64, 64, 32),      # MHA square
    (1, 4, 1, 64, 64, 32),      # MQA (group 4)
    (2, 4, 2, 64, 64, 32),      # GQA 2:1
])
def test_flash_grad_parity(b, h, hkv, sq, skv, d, causal):
    q = rnd((b, sq, h, d), seed=1)
    k = rnd((b, skv, hkv, d), seed=2)
    v = rnd((b, skv, hkv, d), seed=3)
    gk, gr = grad_pair(q, k, v, causal)
    for got, want, name in zip(gk, gr, "qkv"):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=5e-4,
                                   err_msg=f"d{name} mismatch")


def test_flash_grad_parity_padded_lengths():
    """Sequences that do not divide the block get padded + masked inside
    flash_mha; gradients must not leak into (or out of) the padding."""
    q = rnd((2, 50, 4, 32), seed=1)
    k = rnd((2, 50, 2, 32), seed=2)
    v = rnd((2, 50, 2, 32), seed=3)
    gk, gr = grad_pair(q, k, v, causal=True)
    for got, want in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=5e-4)


@pytest.mark.parametrize("causal", [True, False])
def test_flash_grad_parity_kv_valid_len(causal):
    """Right-padded prefill: per-batch valid lengths mask the kv tail;
    dk/dv for padded positions must be exactly zero."""
    q = rnd((2, 64, 2, 32), seed=1)
    k = rnd((2, 64, 2, 32), seed=2)
    v = rnd((2, 64, 2, 32), seed=3)
    kvl = jnp.asarray([37, 64], jnp.int32)
    gk, gr = grad_pair(q, k, v, causal, kv_valid_len=kvl)
    for got, want in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=5e-4)
    np.testing.assert_array_equal(np.asarray(gk[1][0, 37:]), 0.0)
    np.testing.assert_array_equal(np.asarray(gk[2][0, 37:]), 0.0)


def test_flash_grad_parity_mla_value_dim():
    """MLA shape: value head dim differs from the qk head dim."""
    q = rnd((1, 64, 4, 48), seed=1)
    k = rnd((1, 64, 4, 48), seed=2)
    v = rnd((1, 64, 4, 32), seed=3)
    gk, gr = grad_pair(q, k, v, causal=True)
    for got, want in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=5e-4)


def test_flash_grad_bf16_inputs():
    q = rnd((1, 64, 2, 32), jnp.bfloat16, seed=1)
    k = rnd((1, 64, 2, 32), jnp.bfloat16, seed=2)
    v = rnd((1, 64, 2, 32), jnp.bfloat16, seed=3)
    gk, gr = grad_pair(q, k, v, causal=True)
    for got, want in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=5e-2, atol=5e-2)
        assert got.dtype == jnp.bfloat16


def test_flash_lse_residual_matches_reference():
    """The saved logsumexp residual is the actual row logsumexp."""
    from repro.kernels.flash_attention.flash_attention import (
        flash_attention_fwd,
    )

    q = rnd((2, 64, 32), seed=1)
    k = rnd((2, 64, 32), seed=2)
    v = rnd((2, 64, 32), seed=3)
    _, lse = flash_attention_fwd(q, k, v, causal=True, block_q=32,
                                 block_k=32, interpret=True)
    s = jnp.einsum("bqd,bkd->bqk", q, k) * (32 ** -0.5)
    s = jnp.where(jnp.arange(64)[:, None] >= jnp.arange(64)[None, :],
                  s, -jnp.inf)
    want = jax.nn.logsumexp(s, axis=-1)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_block_skip_fwd_parity():
    """Causal block-skip is a pure traffic/compute optimization — bitwise
    identical outputs with the diagonal skip on and off."""
    from repro.kernels.flash_attention.flash_attention import (
        flash_attention_fwd,
    )

    q = rnd((2, 128, 32), seed=1)
    k = rnd((2, 128, 32), seed=2)
    v = rnd((2, 128, 32), seed=3)
    o_skip, lse_skip = flash_attention_fwd(q, k, v, causal=True, block_q=32,
                                           block_k=32, block_skip=True,
                                           interpret=True)
    o_full, lse_full = flash_attention_fwd(q, k, v, causal=True, block_q=32,
                                           block_k=32, block_skip=False,
                                           interpret=True)
    np.testing.assert_allclose(np.asarray(o_skip), np.asarray(o_full),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(lse_skip), np.asarray(lse_full),
                               rtol=1e-6, atol=1e-6)
