"""Round-trip test for the artifact re-analysis path: the roofline can be
recomputed from stored HLO without recompiling, and agrees with what the
dry-run wrote."""

import glob
import gzip
import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                   "dryrun_final")


@pytest.mark.skipif(not glob.glob(os.path.join(ART, "*.hlo.txt.gz")),
                    reason="no dry-run artifacts present")
def test_reanalysis_matches_recorded_roofline():
    from repro.configs.registry import get_config
    from repro.models.config import get_shape
    from repro.roofline.analysis import (
        model_flops,
        parse_hlo_collectives_trip_aware,
        roofline_report,
    )

    checked = 0
    for jf in sorted(glob.glob(os.path.join(ART, "*.json")))[:6]:
        d = json.load(open(jf))
        hf = jf.replace(".json", ".hlo.txt.gz")
        if d.get("status") != "OK" or not os.path.exists(hf):
            continue
        with gzip.open(hf, "rt") as f:
            hlo = f.read()
        colls = parse_hlo_collectives_trip_aware(hlo)
        cfg = get_config(d["arch"])
        cell = get_shape(d["shape"])
        rep = roofline_report(
            flops_per_dev=d["flops_per_dev"],
            bytes_per_dev=d["bytes_per_dev"],
            collectives=colls, n_devices=d["n_devices"],
            model_flops_total=model_flops(cfg, cell.seq_len,
                                          cell.global_batch, cell.kind))
        rec = d["roofline"]
        assert rep["bottleneck"] == rec["bottleneck"], jf
        assert rep["collective_s"] == pytest.approx(rec["collective_s"],
                                                    rel=1e-6), jf
        assert rep["compute_s"] == pytest.approx(rec["compute_s"], rel=1e-6)
        checked += 1
    assert checked >= 3


@pytest.mark.skipif(not glob.glob(os.path.join(ART, "*.json")),
                    reason="no dry-run artifacts present")
def test_all_final_artifacts_compiled():
    """The deliverable: every runnable cell has an OK artifact on both
    meshes; skips are exactly the documented long_500k full-attention set."""
    rows = [json.load(open(f))
            for f in glob.glob(os.path.join(ART, "*.json"))]
    assert len(rows) == 80  # 10 archs x 4 shapes x 2 meshes
    fails = [r for r in rows if r["status"] == "FAIL"]
    assert not fails, [(r["arch"], r["shape"], r["mesh"]) for r in fails]
    skips = {(r["arch"], r["shape"]) for r in rows if r["status"] == "SKIP"}
    assert all(s == "long_500k" for _, s in skips)
    assert {a for a, _ in skips} == {
        "granite-moe-1b-a400m", "internvl2-1b", "minicpm3-4b",
        "olmoe-1b-7b", "qwen1.5-110b", "qwen1.5-32b", "qwen2-1.5b",
        "whisper-small"}
