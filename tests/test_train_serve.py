"""Train/serve layer tests: loss math, accumulation, checkpoint restart,
and prefill/decode consistency against the training-time forward pass."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import reduced_config
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.models.config import ModelConfig
from repro.models.lm import Model
from repro.optim.optimizer import AdamWConfig
from repro.train.step import (
    chunked_xent_loss,
    init_train_state,
    make_loss_fn,
    make_train_step,
)
from repro.train.trainer import Trainer, TrainerConfig

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, max_seq=64)


def _batch(cfg, b=2, s=16, seed=0):
    data = SyntheticPipeline(DataConfig(
        vocab=cfg.vocab, seq_len=s, global_batch=b, seed=seed,
        n_frontend_tokens=cfg.n_frontend_tokens, d_model=cfg.d_model))
    return data.batch_at(0)


# ---------------------------------------------------------------------------
# Loss math
# ---------------------------------------------------------------------------

def test_chunked_xent_matches_full():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 16, 32))
    w = jax.random.normal(jax.random.fold_in(key, 1), (32, 128))
    t = jax.random.randint(jax.random.fold_in(key, 2), (2, 16), 0, 128)
    mask = jnp.ones((2, 16)).at[:, -1].set(0.0)
    full = chunked_xent_loss(x, w, t, mask, n_chunks=1)
    chunked = chunked_xent_loss(x, w, t, mask, n_chunks=4)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               rtol=1e-5)


def test_chunked_xent_grads_match():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 8, 16))
    w = jax.random.normal(jax.random.fold_in(key, 1), (16, 64))
    t = jax.random.randint(jax.random.fold_in(key, 2), (2, 8), 0, 64)
    mask = jnp.ones((2, 8))
    g1 = jax.grad(lambda a, b: chunked_xent_loss(a, b, t, mask, 1),
                  argnums=(0, 1))(x, w)
    g4 = jax.grad(lambda a, b: chunked_xent_loss(a, b, t, mask, 4),
                  argnums=(0, 1))(x, w)
    for a, b in zip(g1, g4):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_grad_accumulation_equivalence():
    model = Model(TINY, compute_dtype=jnp.float32)
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = init_train_state(model, jax.random.PRNGKey(0))
    batch = _batch(TINY, b=4, s=16)
    s1, m1 = jax.jit(make_train_step(model, opt, vocab_chunks=1))(state, batch)
    s2, m2 = jax.jit(make_train_step(model, opt, vocab_chunks=1,
                                     accum_steps=2))(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    l1 = jax.tree.leaves(s1.params)
    l2 = jax.tree.leaves(s2.params)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_loss_decreases_tiny():
    """Train 25 tiny steps, then compare the loss on a *fixed* batch under
    the initial vs trained params.  (The per-step history compares losses
    of different random batches, whose spread at this batch size is larger
    than 25 steps of progress — a coin flip, not a learning signal.)"""
    model = Model(TINY, compute_dtype=jnp.float32)
    data = SyntheticPipeline(DataConfig(vocab=TINY.vocab, seq_len=32,
                                        global_batch=4, seed=1))
    opt = AdamWConfig(lr=2e-3, warmup_steps=2, total_steps=30)
    tr = Trainer(model, data, opt,
                 TrainerConfig(total_steps=25, vocab_chunks=2))
    state, hist = tr.run(jax.random.PRNGKey(0))
    losses = [m["loss"] for _, m in hist]
    assert np.isfinite(losses).all()

    loss_fn = jax.jit(make_loss_fn(model, vocab_chunks=2))
    init_state = init_train_state(model, jax.random.PRNGKey(0))
    fixed = data.batch_at(0)
    before = float(loss_fn(init_state.params, fixed))
    after = float(loss_fn(state.params, fixed))
    assert after < before - 0.05, (before, after)


def test_checkpoint_restart_exact(tmp_path):
    ckpt = str(tmp_path / "ck")
    model = Model(TINY, compute_dtype=jnp.float32)
    data = SyntheticPipeline(DataConfig(vocab=TINY.vocab, seq_len=16,
                                        global_batch=2, seed=2))
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)

    cfg = TrainerConfig(total_steps=10, checkpoint_every=5,
                        checkpoint_dir=ckpt, vocab_chunks=1)
    tr = Trainer(model, data, opt, cfg)
    _s, hist_full = tr.run(jax.random.PRNGKey(0))

    # fresh trainer resumes at step 10's checkpoint... simulate preemption at
    # step 5 by re-running with total 10 from the step-5 checkpoint dir copy
    # -> simpler: run 5 steps into a new dir, resume to 10, compare losses.
    ckpt2 = str(tmp_path / "ck2")
    tr_a = Trainer(model, data, opt, TrainerConfig(
        total_steps=5, checkpoint_every=5, checkpoint_dir=ckpt2,
        vocab_chunks=1))
    tr_a.run(jax.random.PRNGKey(0))
    tr_b = Trainer(model, data, opt, TrainerConfig(
        total_steps=10, checkpoint_every=5, checkpoint_dir=ckpt2,
        vocab_chunks=1))
    _s2, hist_resumed = tr_b.run(jax.random.PRNGKey(0))
    assert hist_resumed[0][0] == 5  # resumed, not restarted
    np.testing.assert_allclose(
        hist_full[-1][1]["loss"], hist_resumed[-1][1]["loss"], rtol=1e-5)


# ---------------------------------------------------------------------------
# Prefill / decode consistency (every family)
# ---------------------------------------------------------------------------

PREFILL_ARCHS = ["qwen2-1.5b", "minicpm3-4b", "olmoe-1b-7b", "rwkv6-7b",
                 "zamba2-2.7b", "whisper-small", "internvl2-1b"]


@pytest.mark.parametrize("arch", PREFILL_ARCHS)
def test_prefill_decode_matches_forward(arch):
    import dataclasses

    cfg = reduced_config(arch)
    if cfg.family == "moe":
        # MoE capacity dropping is length-dependent; pin a no-drop capacity
        # so train-forward and prefill/decode compute identical functions
        cfg = dataclasses.replace(cfg, capacity_factor=8.0,
                                  infer_capacity_factor=8.0)
    model = Model(cfg, compute_dtype=jnp.float32, remat=False)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    b, s_prompt, s_total = 2, 6, 9
    batch = _batch(cfg, b=b, s=s_total, seed=3)
    tokens = batch["tokens"]

    # reference: training-time forward over the full sequence
    ref_logits = model.forward(params, batch)          # (B, S, V)

    # prefill on the prompt prefix
    pre_batch = dict(batch, tokens=tokens[:, :s_prompt])
    max_seq = s_total + cfg.n_frontend_tokens + 2
    logits_p, cache = model.prefill(params, pre_batch, max_seq)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(ref_logits[:, s_prompt - 1]),
                               rtol=5e-3, atol=5e-3)

    # decode the remaining tokens one by one
    offset = cfg.n_frontend_tokens if cfg.family == "vlm" else 0
    for t in range(s_prompt, s_total):
        pos = jnp.full((b,), t + offset, jnp.int32)
        logits_d, cache = model.decode_step(params, cache, tokens[:, t], pos)
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray(ref_logits[:, t]),
            rtol=5e-3, atol=5e-3,
            err_msg=f"{arch} decode step {t}")


def test_serve_engine_slots():
    from repro.serve.engine import Request, ServeEngine

    cfg = reduced_config("qwen2-1.5b")
    model = Model(cfg, compute_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, max_seq=48, batch_slots=2,
                         temperature=0.0, seed=0)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        int(rng.integers(3, 10))).tolist(),
                    max_new_tokens=int(rng.integers(2, 6)))
            for i in range(5)]
    results = engine.serve(reqs)
    assert set(results) == set(range(5))
    for r in reqs:
        assert len(results[r.uid]) == r.max_new_tokens


def test_generate_greedy_matches_decode_path():
    from repro.serve.engine import ServeEngine

    cfg = reduced_config("qwen2-1.5b")
    model = Model(cfg, compute_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(1))
    engine = ServeEngine(model, params, max_seq=32, batch_slots=2)
    prompts = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, cfg.vocab)
    out = engine.generate(prompts, n_tokens=5)
    assert out.shape == (2, 5)
    assert bool(jnp.all(out >= 0)) and bool(jnp.all(out < cfg.vocab))
