"""Per-kernel Pallas (interpret=True) vs pure-jnp oracle, swept over
shapes/dtypes.  Every kernel targets TPU BlockSpec tiling; interpret mode
executes the identical kernel body on CPU."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.warp_ops.ops import shfl_op, vote_op
from repro.kernels.warp_ops.ref import shfl_ref, vote_ref
from repro.kernels.tile_reduce.ops import tile_reduce_op
from repro.kernels.tile_reduce.ref import tile_reduce_ref
from repro.kernels.rmsnorm.ops import rmsnorm_op
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.flash_attention.ops import mha_op
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.matmul.ops import matmul_op
from repro.kernels.matmul.ref import matmul_ref
from repro.kernels.mse.ops import mse_op
from repro.kernels.mse.ref import mse_ref
from repro.kernels.moe_gating.ops import moe_gating_op
from repro.kernels.moe_gating.ref import moe_gating_ref


def rnd(shape, dtype=jnp.float32, seed=0, scale=1.0):
    key = jax.random.PRNGKey(seed)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# warp_ops (vx_shfl / vx_vote)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,w", [(8, 32), (64, 32), (32, 64), (16, 128)])
@pytest.mark.parametrize("mode,imm", [("up", 3), ("down", 5), ("bfly", 4),
                                      ("idx", 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
def test_shfl_kernel_vs_ref(n, w, mode, imm, dtype):
    x = rnd((n, w), jnp.float32, seed=n + imm) * 10
    x = x.astype(dtype)
    got = shfl_op(x, mode, imm, interpret=True)
    want = shfl_ref(x, mode, imm)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n,w", [(8, 32), (64, 32), (16, 8)])
@pytest.mark.parametrize("mode", ["all", "any", "uni", "ballot"])
def test_vote_kernel_vs_ref(n, w, mode):
    key = jax.random.PRNGKey(n)
    pred = jax.random.bernoulli(key, 0.5, (n, w)).astype(jnp.int32)
    if mode == "uni":
        pred = pred.at[: n // 2].set(1)  # some uniform warps
    got = vote_op(pred, mode, interpret=True)
    want = vote_ref(pred, mode)
    if mode == "ballot":
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    else:
        np.testing.assert_array_equal(np.asarray(got) != 0,
                                      np.asarray(want) != 0)


def test_vote_kernel_member_mask():
    pred = jnp.array([[1, 0, 1, 1, 1, 1, 1, 1]], jnp.int32)
    member = jnp.array([[1, 0, 1, 1, 1, 1, 1, 1]], jnp.int32)
    got = vote_op(pred, "all", member, interpret=True)
    assert bool(np.asarray(got).all())


# ---------------------------------------------------------------------------
# tile_reduce (vx_tile + cg::reduce)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,w", [(16, 32), (128, 64), (32, 128)])
@pytest.mark.parametrize("tile", [4, 8, 32])
@pytest.mark.parametrize("op", ["sum", "max", "min"])
def test_tile_reduce_kernel_vs_ref(n, w, tile, op):
    if tile > w:
        pytest.skip("tile exceeds warp")
    x = rnd((n, w), seed=n + tile) * 4
    got = tile_reduce_op(x, tile, op, interpret=True)
    want = tile_reduce_ref(x, tile, op)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_tile_reduce_dtypes(dtype):
    x = (rnd((32, 32), seed=3) * 8).astype(dtype)
    got = tile_reduce_op(x, 8, "max", interpret=True)
    want = tile_reduce_ref(x, 8, "max")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(4, 256), (2, 16, 512), (128, 1024),
                                   (3, 7, 2048)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_kernel_vs_ref(shape, dtype):
    x = rnd(shape, seed=shape[-1]).astype(dtype)
    w = (1.0 + rnd((shape[-1],), seed=1) * 0.1).astype(dtype)
    got = rmsnorm_op(x, w, interpret=True)
    want = rmsnorm_ref(x, w)
    rtol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=rtol,
                               atol=rtol)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,hkv,sq,skv,d", [
    (2, 4, 4, 128, 128, 64),     # MHA square
    (1, 8, 2, 256, 256, 64),     # GQA 4:1
    (1, 4, 4, 128, 384, 64),     # cross/kv-longer (non-causal)
    (2, 2, 1, 64, 64, 128),      # MQA, head_dim 128
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_vs_ref(b, h, hkv, sq, skv, d, causal):
    if causal and sq != skv:
        pytest.skip("causal requires square")
    q = rnd((b, sq, h, d), seed=1)
    k = rnd((b, skv, hkv, d), seed=2)
    v = rnd((b, skv, hkv, d), seed=3)
    got = mha_op(q, k, v, causal=causal, block_q=64, block_k=64,
                 interpret=True)
    group = h // hkv
    kq = jnp.repeat(k, group, axis=2) if group > 1 else k
    vq = jnp.repeat(v, group, axis=2) if group > 1 else v
    want = jnp.stack([
        attention_ref(q[:, :, i].reshape(b, sq, d),
                      kq[:, :, i].reshape(b, skv, d),
                      vq[:, :, i].reshape(b, skv, d), causal=causal)
        for i in range(h)], axis=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q = rnd((1, 128, 2, 64), seed=4).astype(dtype)
    k = rnd((1, 128, 2, 64), seed=5).astype(dtype)
    v = rnd((1, 128, 2, 64), seed=6).astype(dtype)
    got = mha_op(q, k, v, causal=True, block_q=64, block_k=64, interpret=True)
    want = jnp.stack([
        attention_ref(q[:, :, i], k[:, :, i], v[:, :, i], causal=True)
        for i in range(2)], axis=2)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_flash_attention_blocks_divide_unevenly_guard():
    """Kernel requires seq % block == 0 handled by block clamping."""
    q = rnd((1, 96, 1, 64), seed=7)
    k = rnd((1, 96, 1, 64), seed=8)
    v = rnd((1, 96, 1, 64), seed=9)
    got = mha_op(q, k, v, causal=True, block_q=96, block_k=96, interpret=True)
    want = attention_ref(q[:, :, 0], k[:, :, 0], v[:, :, 0], causal=True)
    np.testing.assert_allclose(np.asarray(got[:, :, 0]), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_arbitrary_length_padding():
    """Lengths that don't divide the block are padded + masked internally."""
    from repro.kernels.flash_attention.ops import flash_mha

    q = rnd((1, 100, 2, 64), seed=7)
    k = rnd((1, 100, 2, 64), seed=8)
    v = rnd((1, 100, 2, 64), seed=9)
    got = flash_mha(q, k, v, causal=True, block_q=32, block_k=32,
                    interpret=True)
    want = jnp.stack([
        attention_ref(q[:, :, i], k[:, :, i], v[:, :, i], causal=True)
        for i in range(2)], axis=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_kv_valid_len(causal):
    """Per-batch valid-length masking (right-padded prefill batches)."""
    from repro.kernels.flash_attention.ops import flash_mha

    q = rnd((2, 64, 2, 32), seed=4)
    k = rnd((2, 64, 2, 32), seed=5)
    v = rnd((2, 64, 2, 32), seed=6)
    kvl = jnp.asarray([37, 64], jnp.int32)
    got = flash_mha(q, k, v, causal=causal, kv_valid_len=kvl,
                    block_q=32, block_k=32, interpret=True)
    for bi, l in enumerate([37, 64]):
        want = jnp.stack([
            attention_ref(q[bi:bi + 1, :, i], k[bi:bi + 1, :l, i],
                          v[bi:bi + 1, :l, i], causal=causal)
            for i in range(2)], axis=2)
        # causal rows past the valid length attend the full valid prefix,
        # so every row is well-defined and comparable against the ref
        np.testing.assert_allclose(np.asarray(got[bi:bi + 1]),
                                   np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 512, 128),
                                   (512, 256, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_kernel_vs_ref(m, k, n, dtype):
    a = rnd((m, k), seed=m).astype(dtype)
    b = rnd((k, n), seed=n).astype(dtype)
    got = matmul_op(a, b, block_m=128, block_n=128, block_k=128,
                    interpret=True)
    want = matmul_ref(a, b)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol,
                               atol=tol * 8)


# ---------------------------------------------------------------------------
# mse (unet.cu mse_forward)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1024, 8192, 65536])
@pytest.mark.parametrize("warp_size", [32, 128])
def test_mse_kernel_vs_ref(n, warp_size):
    p = rnd((n,), seed=1)
    t = rnd((n,), seed=2)
    got = mse_op(p, t, warp_size=warp_size, interpret=True)
    want = mse_ref(p, t)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


# ---------------------------------------------------------------------------
# moe gating
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,e,k", [(64, 32, 8), (256, 64, 8), (128, 128, 2),
                                   (512, 64, 1)])
def test_moe_gating_kernel_vs_ref(t, e, k):
    logits = rnd((t, e), seed=t + e) * 2
    w_got, m_got = moe_gating_op(logits, k, interpret=True)
    w_want, m_want = moe_gating_ref(logits, k)
    np.testing.assert_array_equal(np.asarray(m_got), np.asarray(m_want))
    np.testing.assert_allclose(np.asarray(w_got), np.asarray(w_want),
                               rtol=1e-5, atol=1e-6)
    # combine weights sum to 1 over selected experts
    np.testing.assert_allclose(np.asarray(w_got.sum(-1)), 1.0, rtol=1e-5)


def test_moe_gating_tie_break_deterministic():
    logits = jnp.zeros((4, 16))  # all ties -> lowest expert ids win
    w, m = moe_gating_op(logits, 4, interpret=True)
    expect = np.zeros((4, 16), np.int32)
    expect[:, :4] = 1
    np.testing.assert_array_equal(np.asarray(m), expect)
