"""Fault-tolerance contracts: straggler watchdog, preemption hook,
exact-resume after preemption, data-pipeline determinism."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.models.config import ModelConfig
from repro.models.lm import Model
from repro.optim.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig

TINY = ModelConfig(name="tiny-ft", family="dense", n_layers=1, d_model=32,
                   n_heads=2, n_kv_heads=1, d_ff=64, vocab=128, max_seq=32)


def _trainer(tmp=None, total=10, every=3):
    model = Model(TINY, compute_dtype=jnp.float32)
    data = SyntheticPipeline(DataConfig(vocab=TINY.vocab, seq_len=16,
                                        global_batch=2, seed=4))
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=total)
    return Trainer(model, data, opt, TrainerConfig(
        total_steps=total, checkpoint_every=every, checkpoint_dir=tmp,
        vocab_chunks=1))


def test_straggler_watchdog_flags_slow_steps():
    tr = _trainer()
    for step, dt in enumerate([0.1] * 10):
        tr._watchdog(step, dt)
    assert not tr.straggler_events
    tr._watchdog(10, 1.0)  # 10x the median
    assert len(tr.straggler_events) == 1
    ev = tr.straggler_events[0]
    assert ev["step"] == 10 and ev["duration"] == 1.0


def test_preemption_checkpoints_and_resumes_exactly(tmp_path):
    ckpt = str(tmp_path / "ck")
    # uninterrupted reference
    ref_tr = _trainer(None, total=8, every=100)
    _, ref_hist = ref_tr.run(jax.random.PRNGKey(0))

    # preempt after step 4 (checkpoint_every=100 -> only the preemption
    # checkpoint exists), then resume to completion
    tr = _trainer(ckpt, total=8, every=100)
    fired = {"n": 0}

    def should_stop():
        fired["n"] += 1
        return fired["n"] == 5  # after the 5th step (step index 4)

    _, hist1 = tr.run(jax.random.PRNGKey(0), should_stop=should_stop)
    assert hist1[-1][0] == 4  # stopped early
    tr2 = _trainer(ckpt, total=8, every=100)
    _, hist2 = tr2.run(jax.random.PRNGKey(0))
    assert hist2[0][0] == 5  # resumed, not restarted
    np.testing.assert_allclose(ref_hist[-1][1]["loss"],
                               hist2[-1][1]["loss"], rtol=1e-5)


def test_pipeline_stateless_determinism():
    cfg = DataConfig(vocab=512, seq_len=64, global_batch=4, seed=11)
    a = SyntheticPipeline(cfg).batch_at(123)["tokens"]
    b = SyntheticPipeline(cfg).batch_at(123)["tokens"]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = SyntheticPipeline(cfg).batch_at(124)["tokens"]
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_async_checkpointer_commits_and_resumes(tmp_path):
    ckpt = str(tmp_path / "ck_async")
    model = Model(TINY, compute_dtype=jnp.float32)
    data = SyntheticPipeline(DataConfig(vocab=TINY.vocab, seq_len=16,
                                        global_batch=2, seed=4))
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=6)
    cfg = TrainerConfig(total_steps=6, checkpoint_every=2,
                        checkpoint_dir=ckpt, vocab_chunks=1,
                        async_checkpoint=True, keep_checkpoints=2)
    tr = Trainer(model, data, opt, cfg)
    _, hist = tr.run(jax.random.PRNGKey(0))

    # sync-path reference must produce identical committed state
    ckpt2 = str(tmp_path / "ck_sync")
    cfg2 = TrainerConfig(total_steps=6, checkpoint_every=2,
                         checkpoint_dir=ckpt2, vocab_chunks=1,
                         keep_checkpoints=2)
    Trainer(model, data, opt, cfg2).run(jax.random.PRNGKey(0))

    from repro.checkpoint.checkpoint import latest_step, restore_checkpoint
    from repro.train.step import init_train_state

    assert latest_step(ckpt) == latest_step(ckpt2) == 6
    like = init_train_state(model, jax.random.PRNGKey(0))
    a, _, _ = restore_checkpoint(ckpt, like)
    b, _, _ = restore_checkpoint(ckpt2, like)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
