"""Fault-tolerant serving: lifecycle statuses, deterministic injection,
invariant audit, recovery, and graceful degradation.

The load-bearing property everything here leans on: engine outputs are a
pure function of (params, prompt, uid, temperature) — admission order,
slot assignment, preemption, retry, and backend all cancel out.  So a
faulted serve must return bit-identical tokens for every request that
still finishes OK, and the audit sweep must come back clean whatever the
schedule did to the allocator."""

import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import reduced_config
from repro.models.lm import Model
from repro.serve import (
    STATUS_CANCELLED,
    STATUS_FAILED,
    STATUS_OK,
    STATUS_SHED,
    STATUS_TIMEOUT,
    TERMINAL_STATUSES,
    AuditError,
    Fault,
    FaultSchedule,
    InjectedFault,
    PageAllocator,
    PagedCacheManager,
    Request,
    ServeEngine,
)
from repro.serve.audit import audit_manager

try:
    from hypothesis import given, settings
    from hypothesis import strategies as hyp_st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

_CACHE = {}


def _model(arch="qwen2-1.5b"):
    if arch not in _CACHE:
        cfg = reduced_config(arch)
        model = Model(cfg, compute_dtype=jnp.float32)
        params = model.init(jax.random.PRNGKey(1))
        _CACHE[arch] = (cfg, model, params)
    return _CACHE[arch]


def _engine(**kw):
    cfg, model, params = _model()
    kw = {"max_seq": 48, "batch_slots": 2, "temperature": 0.0, "seed": 0,
          "cache_layout": "paged", "page_size": 8, **kw}
    return ServeEngine(model, params, **kw)


def _reqs(n, seed=3, plo=3, phi=12, mlo=2, mhi=7, **fields):
    cfg, _, _ = _model()
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        int(rng.integers(plo, phi))).tolist(),
                    max_new_tokens=int(rng.integers(mlo, mhi)), **fields)
            for i in range(n)]


def _grow_reqs(n, max_new=8, **fields):
    """6-token prompts on an 8-token page: with admission at round 0, the
    first growth allocation lands at round 2 exactly (positions 6 and 7
    fill the prompt's page, position 8 opens block 1) — what lets the
    hard-OOM tests pin their injection to a round that provably
    allocates."""
    cfg, _, _ = _model()
    return [Request(uid=i,
                    prompt=[(i * 7 + j) % cfg.vocab for j in range(6)],
                    max_new_tokens=max_new, **fields)
            for i in range(n)]


def _statuses(eng):
    return {u: s["status"] for u, s in eng.last_stats.items()
            if isinstance(u, int)}


def _assert_clean(eng):
    p = eng.last_pool_stats
    assert p is not None and p.audit_ok, p.audit_errors
    assert p.used_pages == 0


# ---------------------------------------------------------------------------
# FaultSchedule: deterministic, replayable
# ---------------------------------------------------------------------------

def test_fault_schedule_deterministic():
    a = FaultSchedule.random(7, uids=(0, 1, 2))
    b = FaultSchedule.random(7, uids=(0, 1, 2))
    assert a.faults == b.faults
    assert FaultSchedule.random(8, uids=(0, 1, 2)).faults != a.faults


def test_fault_validation():
    with pytest.raises(ValueError):
        Fault("not-a-kind", step=0)
    with pytest.raises(ValueError):
        Fault("nan", step=-1)
    f = Fault("nan", step=3, span=2)
    assert not f.active_at(2) and f.active_at(3) and f.active_at(4) \
        and not f.active_at(5)


def test_corruption_target_seeded():
    fs = FaultSchedule([Fault("page_corruption", step=1)], seed=4)
    f = fs.faults[0]
    pick = fs.corruption_target(f, 1, [5, 9, 2])
    assert pick == fs.corruption_target(f, 1, [9, 2, 5])  # order-free
    assert pick in (2, 5, 9)
    assert fs.corruption_target(f, 1, []) is None
    assert fs.corruption_target(Fault("page_corruption", step=1, page=7),
                                1, [1, 2]) == 7


# ---------------------------------------------------------------------------
# status taxonomy: shed / timeout / cancel
# ---------------------------------------------------------------------------

def test_shed_reject_newest():
    reqs = _reqs(6)
    eng = _engine()
    base = eng.serve(copy.deepcopy(reqs))
    eng2 = _engine(max_queue=4, shed_policy="reject-newest")
    out = eng2.serve(copy.deepcopy(reqs))
    stt = _statuses(eng2)
    assert [stt[u] for u in (4, 5)] == [STATUS_SHED] * 2
    assert all(stt[u] == STATUS_OK for u in (0, 1, 2, 3))
    assert out == {u: base[u] for u in (0, 1, 2, 3)}
    assert "queue overflow" in eng2.last_stats[5]["reason"]
    _assert_clean(eng2)


def test_shed_reject_largest():
    reqs = _reqs(6)
    eng = _engine(max_queue=4, shed_policy="reject-largest")
    eng.serve(copy.deepcopy(reqs))
    stt = _statuses(eng)
    sizes = {r.uid: len(r.prompt) + r.max_new_tokens for r in reqs}
    shed = {u for u, v in stt.items() if v == STATUS_SHED}
    assert len(shed) == 2
    kept = set(stt) - shed
    assert max(sizes[u] for u in kept) <= min(sizes[u] for u in shed)


def test_shed_policy_validated():
    with pytest.raises(ValueError):
        _engine(shed_policy="nope")
    with pytest.raises(ValueError):
        _engine(max_queue=0)


def test_cancel_queued_and_live():
    reqs = _reqs(6, mlo=6, mhi=10)
    eng = _engine()
    base = eng.serve(copy.deepcopy(reqs))
    # cancel one late-queued request before serving, one live mid-flight
    eng.cancel(5)
    fs = FaultSchedule([Fault("cancel", step=2, uid=0)])
    out = eng.serve(copy.deepcopy(reqs), faults=fs)
    stt = _statuses(eng)
    assert stt[5] == STATUS_CANCELLED and stt[0] == STATUS_CANCELLED
    assert 0 not in out and 5 not in out
    for u, toks in out.items():
        assert toks == base[u]
    assert not eng._cancel_uids        # consumed
    _assert_clean(eng)


def test_forced_deadline_timeout():
    reqs = _reqs(4, mlo=6, mhi=10)
    eng = _engine()
    base = eng.serve(copy.deepcopy(reqs))
    fs = FaultSchedule([Fault("deadline", step=3, uid=1)])
    out = eng.serve(copy.deepcopy(reqs), faults=fs)
    stt = _statuses(eng)
    assert stt[1] == STATUS_TIMEOUT
    assert eng.last_stats[1]["reason"] == "deadline"
    for u, toks in out.items():
        assert toks == base[u]
    _assert_clean(eng)


def test_wall_clock_deadline():
    # a deadline that has already passed expires at the first round
    reqs = _reqs(3)
    reqs[1].deadline_ms = 0.0
    eng = _engine()
    out = eng.serve(reqs)
    stt = _statuses(eng)
    assert stt[1] == STATUS_TIMEOUT and 1 not in out
    assert stt[0] == stt[2] == STATUS_OK


def test_ttft_deadline():
    # far-future TTFT deadlines never fire; an already-expired one kills
    # the request before it is ever admitted
    reqs = _reqs(3, ttft_deadline_ms=1e9)
    eng = _engine()
    eng.serve(reqs)
    assert set(_statuses(eng).values()) == {STATUS_OK}
    reqs2 = _reqs(3)
    reqs2[2].ttft_deadline_ms = 0.0
    out2 = eng.serve(reqs2)
    assert _statuses(eng)[2] == STATUS_TIMEOUT and 2 not in out2
    assert eng.last_stats[2]["reason"] == "ttft_deadline"


def test_duplicate_uid_rejected():
    eng = _engine()
    with pytest.raises(ValueError, match="duplicate"):
        eng.serve([Request(uid=1, prompt=[1, 2], max_new_tokens=2),
                   Request(uid=1, prompt=[3, 4], max_new_tokens=2)])


# ---------------------------------------------------------------------------
# NaN quarantine: only the targeted request fails
# ---------------------------------------------------------------------------

def test_nan_quarantines_only_target():
    reqs = _reqs(6, mlo=6, mhi=10)
    eng = _engine()
    base = eng.serve(copy.deepcopy(reqs))
    fs = FaultSchedule([Fault("nan", step=1, uid=0, span=2)])
    out = eng.serve(copy.deepcopy(reqs), faults=fs)
    stt = _statuses(eng)
    assert stt[0] == STATUS_FAILED
    assert eng.last_stats[0]["reason"] == "nan-logits"
    assert all(v == STATUS_OK for u, v in stt.items() if u != 0)
    assert 0 not in out
    for u, toks in out.items():
        assert toks == base[u]            # batchmates bit-identical
    _assert_clean(eng)


def test_nan_untargeted_fails_all_live():
    reqs = _reqs(4, mlo=6, mhi=10)
    eng = _engine()
    # wide window, no uid: every request dies at its first decode step
    fs = FaultSchedule([Fault("nan", step=0, span=64)])
    out = eng.serve(copy.deepcopy(reqs), faults=fs)
    stt = _statuses(eng)
    assert not out
    assert all(v == STATUS_FAILED for v in stt.values())
    _assert_clean(eng)


def test_page_corruption_surfaces_as_quarantine():
    reqs = _reqs(4, mlo=6, mhi=10)
    eng = _engine()
    base = eng.serve(copy.deepcopy(reqs))
    fs = FaultSchedule([Fault("page_corruption", step=2)], seed=11)
    out = eng.serve(copy.deepcopy(reqs), faults=fs)
    stt = _statuses(eng)
    assert STATUS_FAILED in stt.values()  # someone read the poisoned page
    for u, toks in out.items():
        assert toks == base[u]
    _assert_clean(eng)


# ---------------------------------------------------------------------------
# exception safety: mid-step failures leave no slot or page held
# ---------------------------------------------------------------------------

def test_fatal_oom_aborts_audit_clean():
    reqs = _grow_reqs(4)
    eng = _engine()
    base = eng.serve(copy.deepcopy(reqs))
    fs = FaultSchedule([Fault("oom", step=2, raise_exc=True, fatal=True)])
    with pytest.raises(InjectedFault):
        eng.serve(copy.deepcopy(reqs), faults=fs)
    stt = _statuses(eng)
    assert all(v in TERMINAL_STATUSES for v in stt.values())
    assert STATUS_FAILED in stt.values()
    _assert_clean(eng)                    # all pages released on the way out
    # the engine is reusable: the very next serve() is fault-free-correct
    assert eng.serve(copy.deepcopy(reqs)) == base


def test_fatal_kernel_exception_aborts_audit_clean():
    reqs = _reqs(4, mlo=6, mhi=10)
    eng = _engine()
    base = eng.serve(copy.deepcopy(reqs))
    fs = FaultSchedule([Fault("kernel", step=1, fatal=True)])
    with pytest.raises(InjectedFault):
        eng.serve(copy.deepcopy(reqs), faults=fs)
    _assert_clean(eng)
    assert eng.serve(copy.deepcopy(reqs)) == base


# ---------------------------------------------------------------------------
# recovery: step restart, capped retries, kernel -> SW degradation
# ---------------------------------------------------------------------------

def test_hard_oom_recovers_bit_identical():
    reqs = _grow_reqs(5)
    eng = _engine()
    base = eng.serve(copy.deepcopy(reqs))
    fs = FaultSchedule([Fault("oom", step=2, raise_exc=True)])
    out = eng.serve(copy.deepcopy(reqs), faults=fs)
    assert eng.recoveries == 1
    assert out == base                    # replay is exact
    assert all(v == STATUS_OK for v in _statuses(eng).values())
    retried = sum(s["retries"] for u, s in eng.last_stats.items()
                  if isinstance(u, int))
    assert retried >= 1                   # someone paid a retry
    _assert_clean(eng)


def test_retry_budget_exhausts_to_failed():
    reqs = _grow_reqs(3, max_retries=0)
    eng = _engine(max_recoveries=4)
    fs = FaultSchedule([Fault("oom", step=2, raise_exc=True)])
    out = eng.serve(copy.deepcopy(reqs), faults=fs)
    stt = _statuses(eng)
    # the two live rows had no retry budget; the queued one rode through
    assert stt[0] == stt[1] == STATUS_FAILED
    assert stt[2] == STATUS_OK and 2 in out
    assert "retries exhausted" in eng.last_stats[0]["reason"]
    _assert_clean(eng)


def test_max_recoveries_cap_propagates():
    reqs = _grow_reqs(3)
    eng = _engine(max_recoveries=1)
    # round 2: growth alloc raises -> recovery #1; round 3: re-admission
    # alloc raises again -> over the cap, escapes
    fs = FaultSchedule([Fault("oom", step=2, raise_exc=True),
                        Fault("oom", step=3, raise_exc=True)])
    with pytest.raises(InjectedFault):
        eng.serve(copy.deepcopy(reqs), faults=fs)
    assert eng.recoveries == 1            # second strike escaped
    _assert_clean(eng)


def test_double_recovery_no_double_fold():
    """Back-to-back recoveries re-requeue already-resumed requests: the
    second fold must absorb only the tokens generated since the first
    (folding the whole accumulator again would duplicate the earlier
    tokens in the resumed prompt and silently corrupt the replay)."""
    reqs = _reqs(4, seed=4, plo=4, phi=10, mlo=8, mhi=9)
    eng = _engine(max_seq=64, max_recoveries=8)
    base = eng.serve(copy.deepcopy(reqs))
    fs = FaultSchedule([Fault("kernel", step=12),
                        Fault("kernel", step=13, span=3)])
    out = eng.serve(copy.deepcopy(reqs), faults=fs)
    assert eng.recoveries >= 2            # the same requests resumed twice
    assert out == base
    assert all(v == STATUS_OK for v in _statuses(eng).values())
    _assert_clean(eng)


def test_kernel_fault_degrades_to_sw():
    reqs = _reqs(5, mlo=6, mhi=10)
    eng = _engine()
    base = eng.serve(copy.deepcopy(reqs))
    assert not eng.backend_degraded
    fs = FaultSchedule([Fault("kernel", step=2)])
    out = eng.serve(copy.deepcopy(reqs), faults=fs)
    assert eng.backend_degraded
    assert eng.model.decode_backend == "jnp"
    assert eng.verify_backend == "jnp"
    assert out == base                    # HW/SW parity after the fallback
    assert all(v == STATUS_OK for v in _statuses(eng).values())
    _assert_clean(eng)


def test_soft_oom_blocks_then_drains():
    """A soft-OOM window denies admission/growth without raising; the
    engine preempts or waits it out and finishes bit-identically."""
    reqs = _reqs(5, mlo=6, mhi=10)
    eng = _engine()
    base = eng.serve(copy.deepcopy(reqs))
    fs = FaultSchedule([Fault("oom", step=0, span=3)])
    out = eng.serve(copy.deepcopy(reqs), faults=fs)
    assert out == base
    assert eng.recoveries == 0            # soft denial never raises
    _assert_clean(eng)


def test_mid_flight_soft_oom_preempts_and_resumes():
    reqs = _grow_reqs(2, max_new=10)
    eng = _engine()
    base = eng.serve(copy.deepcopy(reqs))
    # growth denied at round 2: the newest live request is preempted and
    # requeued; outputs must survive bit-for-bit
    fs = FaultSchedule([Fault("oom", step=2, span=2)])
    out = eng.serve(copy.deepcopy(reqs), faults=fs)
    assert out == base
    assert eng.preemptions >= 1
    _assert_clean(eng)


# ---------------------------------------------------------------------------
# straggler watchdog
# ---------------------------------------------------------------------------

def test_straggler_watchdog_records_event():
    reqs = _reqs(2, mlo=20, mhi=24)       # enough steps to build a median
    eng = _engine(max_seq=64, straggler_factor=3.0)
    fs = FaultSchedule([Fault("straggler", step=12, sleep_s=1.0)])
    out = eng.serve(copy.deepcopy(reqs), faults=fs)
    events = eng.last_stats["stragglers"]
    assert len(events) >= 1
    ev = events[0]
    assert ev["duration_s"] > 3.0 * ev["median_s"]
    assert ev["live_slots"] >= 1
    assert all(v == STATUS_OK for v in _statuses(eng).values())
    assert all(len(t) for t in out.values())


def test_stragglers_key_always_present():
    eng = _engine()
    eng.serve(_reqs(2))
    assert eng.last_stats["stragglers"] == []


# ---------------------------------------------------------------------------
# speculative acceptance collapse -> auto-disable -> cooldown re-enable
# ---------------------------------------------------------------------------

def test_spec_collapse_auto_disables_and_recovers():
    reqs = _reqs(2, seed=5, mlo=30, mhi=34)
    # damp the layer stack so the self-draft tracks the target (as in
    # benchmarks/spec_decode.py): with random-init weights acceptance
    # collapses *naturally* and the governor would fire without a fault
    cfg, model, params = _model()
    params = dict(params, layers=jax.tree.map(lambda a: a * 0.05,
                                              params["layers"]))
    eng = ServeEngine(model, params, max_seq=96, batch_slots=2,
                      temperature=0.0, seed=0, cache_layout="paged",
                      page_size=8, spec_k=4, draft="self:2",
                      spec_disable_window=4, spec_cooldown=4)
    base = eng.serve(copy.deepcopy(reqs))
    assert eng.last_stats[0].get("spec_auto_disables", 0) == 0
    fs = FaultSchedule([Fault("spec_collapse", step=0, uid=0, span=6)])
    out = eng.serve(copy.deepcopy(reqs), faults=fs)
    s = eng.last_stats[0]
    assert s.get("spec_auto_disables", 0) >= 1
    # collapse perturbs only *proposals*: committed values never change
    assert out == base
    assert all(v == STATUS_OK for v in _statuses(eng).values())
    # disabled state is per-serve: a fresh call has it re-armed
    out2 = eng.serve(copy.deepcopy(reqs))
    assert out2 == base
    assert eng.last_stats[0].get("spec_auto_disables", 0) == 0
    _assert_clean(eng)


# ---------------------------------------------------------------------------
# audit: constructed violations are detected
# ---------------------------------------------------------------------------

def test_audit_detects_leaked_refcount():
    mgr = PagedCacheManager(num_pages=8, page_size=4, slots=2, max_seq=16)
    mgr.admit(0, 6)
    assert mgr.audit().ok
    # leak: bump a refcount with no holder to account for it
    page = mgr.owned[0][0]
    mgr.allocator._refs[page] += 1
    mgr.allocator._logical += 1
    rep = mgr.audit()
    assert not rep.ok and rep.refcount_mismatches == 1
    with pytest.raises(AuditError):
        rep.raise_if_failed()


def test_audit_detects_orphan_page():
    mgr = PagedCacheManager(num_pages=8, page_size=4, slots=2, max_seq=16)
    mgr.admit(0, 6)
    # orphan: the table forgets a page the allocator still holds
    page = mgr.owned[0].pop()
    mgr.tables[0, 1] = 0
    rep = mgr.audit()
    assert not rep.ok and rep.orphan_pages == 1
    assert any(f"orphan page {page}" in e for e in rep.errors)


def test_audit_detects_free_list_corruption():
    alloc = PageAllocator(8)
    pages = alloc.alloc(2)
    alloc._free.append(pages[0])          # page both free and allocated
    errs = alloc.audit()
    assert any("both free and allocated" in e for e in errs)


def test_audit_detects_double_mapping():
    mgr = PagedCacheManager(num_pages=8, page_size=4, slots=2, max_seq=16)
    mgr.admit(0, 8)
    mgr.tables[0, 1] = mgr.tables[0, 0]   # one page at two logical blocks
    rep = mgr.audit()
    assert not rep.ok
    assert any("two logical blocks" in e for e in rep.errors)


def test_engine_audit_flag_catches_corruption(monkeypatch):
    """audit=True sweeps every round: a deliberately broken release is
    caught at the step that caused it, as AuditError (never recovered)."""
    reqs = _reqs(3, mlo=4, mhi=7)
    eng = _engine(audit=True)
    eng.serve(copy.deepcopy(reqs))        # clean run under per-round audit
    assert all(v == STATUS_OK for v in _statuses(eng).values())

    real_release = PagedCacheManager.release

    def leaky_release(self, slot):
        if self.owned[slot]:              # drop the bookkeeping, keep refs
            self.owned[slot] = []
            self.tables[slot, :] = 0
            self.dirty = True
            return
        return real_release(self, slot)

    monkeypatch.setattr(PagedCacheManager, "release", leaky_release)
    with pytest.raises(AuditError):
        eng.serve(copy.deepcopy(reqs))


def test_pool_stats_carry_audit_fields():
    eng = _engine()
    eng.serve(_reqs(3))
    p = eng.last_pool_stats
    assert p.audit_ok and p.audit_errors == []
    assert p.audit_orphan_pages == 0 and p.audit_refcount_mismatches == 0


def test_audit_manager_function_directly():
    mgr = PagedCacheManager(num_pages=8, page_size=4, slots=2, max_seq=16)
    mgr.admit(0, 5)
    mgr.admit(1, 4)
    rep = audit_manager(mgr)
    assert rep.ok and rep.errors == []
    mgr.release(0)
    mgr.release(1)
    assert audit_manager(mgr).ok


# ---------------------------------------------------------------------------
# property test: random schedules -> parity + partition + leak-freedom
# ---------------------------------------------------------------------------

def _random_sweep_once(eng, reqs, base, seed):
    fs = FaultSchedule.random(seed, uids=tuple(r.uid for r in reqs),
                              max_step=16)
    out = eng.serve(copy.deepcopy(reqs), faults=fs)
    stt = _statuses(eng)
    assert set(stt) == {r.uid for r in reqs}
    assert all(v in TERMINAL_STATUSES for v in stt.values()), (fs, stt)
    for u, toks in out.items():
        assert stt[u] == STATUS_OK
        assert toks == base[u], (fs, u)
    for u, v in stt.items():
        if v == STATUS_OK:
            assert u in out
    p = eng.last_pool_stats
    assert p.audit_ok, (fs, p.audit_errors)
    assert p.used_pages == 0, fs


@pytest.mark.slow
def test_random_fault_schedules_parity_sweep():
    reqs = _reqs(5, mlo=5, mhi=9)
    eng = _engine(max_recoveries=16)
    base = eng.serve(copy.deepcopy(reqs))
    for seed in range(40):
        _random_sweep_once(eng, reqs, base, seed)


def test_random_fault_schedules_parity_smoke():
    reqs = _reqs(4, mlo=4, mhi=8)
    eng = _engine(max_recoveries=16)
    base = eng.serve(copy.deepcopy(reqs))
    for seed in range(6):
        _random_sweep_once(eng, reqs, base, seed)


if _HAVE_HYPOTHESIS:
    # one shared engine across examples: every example re-jitting its own
    # step functions would turn a property test into a compile benchmark
    _PROP = {}

    def _prop_fixture():
        if not _PROP:
            _PROP["reqs"] = _reqs(4, mlo=4, mhi=8)
            _PROP["eng"] = _engine(max_recoveries=16)
            _PROP["base"] = _PROP["eng"].serve(
                copy.deepcopy(_PROP["reqs"]))
        return _PROP["eng"], _PROP["reqs"], _PROP["base"]

    @settings(max_examples=10, deadline=None)
    @given(seed=hyp_st.integers(min_value=0, max_value=10_000))
    def test_random_fault_schedule_property(seed):
        """For ANY seeded schedule: statuses partition the request set,
        surviving outputs are bit-identical to the fault-free run, and
        the allocator ends leak-free."""
        eng, reqs, base = _prop_fixture()
        _random_sweep_once(eng, reqs, base, seed)
