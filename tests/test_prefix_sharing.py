"""Prefix-sharing paged KV cache: radix-index units, refcounted
copy-on-write admission in the manager (incl. OOM and shared-boundary
retract edges), shared == unshared greedy serving across admission
orders / forced preemption / speculative decoding, page-bound
accounting, and the gather_slot shared-resolution debug view."""

import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import reduced_config
from repro.models.lm import Model
from repro.serve.engine import Request, ServeEngine
from repro.serve.kv_cache import (
    TRASH_PAGE,
    PagedCacheManager,
    gather_slot,
    scatter_prefill,
)
from repro.serve.prefix_index import PrefixIndex

_CACHE = {}


def _model(arch="qwen2-1.5b"):
    if arch not in _CACHE:
        cfg = reduced_config(arch)
        model = Model(cfg, compute_dtype=jnp.float32)
        params = model.init(jax.random.PRNGKey(1))
        _CACHE[arch] = (cfg, model, params)
    return _CACHE[arch]


def _engine(arch="qwen2-1.5b", **kw):
    cfg, model, params = _model(arch)
    kw = {"max_seq": 48, "batch_slots": 2, "temperature": 0.0, "seed": 0,
          "cache_layout": "paged", "page_size": 8, **kw}
    return ServeEngine(model, params, **kw)


def _shared_reqs(n, prefix_len=16, suf_lo=1, suf_hi=8, max_new=5, seed=3,
                 dup_aligned=True):
    """n requests sharing a common ``prefix_len``-token prefix with short
    random suffixes; optionally one exact page-aligned duplicate (the
    copy-on-write admission case)."""
    cfg, _, _ = _model()
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab, prefix_len).tolist()
    reqs = [Request(uid=i,
                    prompt=prefix + rng.integers(
                        0, cfg.vocab, int(rng.integers(suf_lo, suf_hi))
                    ).tolist(),
                    max_new_tokens=max_new)
            for i in range(n)]
    if dup_aligned:
        reqs.append(Request(uid=n, prompt=list(prefix),
                            max_new_tokens=max_new))
    return reqs


def _serve(engine, reqs):
    return engine.serve(copy.deepcopy(reqs))


def _mgr(num_pages, page_size=4, slots=3, max_seq=32):
    return PagedCacheManager(num_pages, page_size, slots, max_seq,
                             prefix_index=PrefixIndex(page_size))


# ---------------------------------------------------------------------------
# radix index units
# ---------------------------------------------------------------------------

def test_index_match_insert_page_granular():
    ix = PrefixIndex(4)
    assert ix.match([1, 2, 3, 4, 5]) == []
    new = ix.insert([1, 2, 3, 4, 5, 6, 7, 8], [10, 11])
    assert new == [10, 11] and len(ix) == 2
    assert ix.match([1, 2, 3, 4, 5, 6, 7, 8, 9]) == [10, 11]
    assert ix.match([1, 2, 3, 4, 9, 9, 9, 9]) == [10]  # diverges at page 2
    assert ix.match([1, 2, 3]) == []     # partial pages never match
    assert ix.match([1, 2, 3, 9, 9]) == []
    # re-insert keeps existing nodes and registers only the new depth
    new2 = ix.insert([1, 2, 3, 4, 5, 6, 7, 8, 9, 9, 9, 9], [10, 11, 12])
    assert new2 == [12] and len(ix) == 3
    # a private duplicate of an indexed page (CoW fork) is not registered
    assert ix.insert([1, 2, 3, 4, 5, 6, 7, 8], [10, 77]) == []
    assert ix.match([1, 2, 3, 4, 5, 6, 7, 8]) == [10, 11]


def test_index_evict_lru_leaf_cascade():
    ix = PrefixIndex(2)
    ix.insert([1, 1, 2, 2, 3, 3], [5, 6, 7])     # chain 5 -> 6 -> 7
    ix.insert([1, 1, 9, 9], [5, 8])              # branch below 5
    held = {6}                                   # a live slot holds page 6
    can = lambda p: p not in held
    freed = ix.evict_lru(10, can)
    # 7 and 8 are evictable leaves; 6 is pinned, which also blocks its
    # ancestor 5 from the cascade
    assert set(freed) == {7, 8} and len(ix) == 2
    assert ix.evictable(can) == 0
    held.clear()
    assert set(ix.evict_lru(10, can)) == {5, 6}
    assert len(ix) == 0


def test_index_evict_lru_order_and_exclude():
    ix = PrefixIndex(2)
    ix.insert([1, 1], [3])
    ix.insert([2, 2], [4])
    ix.match([1, 1])                             # refresh page 3
    assert ix.evict_lru(1, lambda p: True) == [4]
    # exclude masks pages an admission is about to share
    assert ix.evictable(lambda p: True, exclude={3}) == 0


# ---------------------------------------------------------------------------
# manager: refcounted admission, CoW, OOM, shared-boundary retract
# ---------------------------------------------------------------------------

def test_manager_admit_prefix_shares_pages():
    m = _mgr(num_pages=12)
    prompt = list(range(10))                     # 2 full pages + partial
    plan0 = m.plan_admit(prompt)
    assert plan0.cached_tokens == 0 and plan0.private_blocks == 3
    m.admit_prefix(0, plan0)
    m.register_prefix(0, prompt)
    assert len(m.index) == 2
    # identical prompt: shares both full pages, allocates only the tail
    plan1 = m.plan_admit(list(prompt))
    assert plan1.cached_tokens == 8 and plan1.private_blocks == 1
    assert plan1.shared_pages == [int(m.tables[0, 0]), int(m.tables[0, 1])]
    m.admit_prefix(1, plan1)
    assert m.tables[1, 0] == m.tables[0, 0]
    assert m.tables[1, 1] == m.tables[0, 1]
    assert m.tables[1, 2] != m.tables[0, 2]      # private tails differ
    # physical: 3 + 1; logical slot mappings: 3 + 3 (+2 index refs)
    assert m.allocator.used == 4
    assert m.allocator.logical == 8
    # divergence after one page matches one page
    plan2 = m.plan_admit(prompt[:4] + [99] * 6)
    assert plan2.cached_tokens == 4
    assert plan2.shared_pages == [int(m.tables[0, 0])]


def test_manager_cow_fork_on_aligned_full_match():
    m = _mgr(num_pages=10, slots=2)
    prompt = list(range(8))                      # exactly 2 pages
    m.admit_prefix(0, m.plan_admit(prompt))
    m.register_prefix(0, prompt)
    plan = m.plan_admit(list(prompt))
    # the write frontier lands inside the last matched page: fork it
    assert plan.cow_src == int(m.tables[0, 1])
    assert plan.cached_tokens == len(prompt) - 1
    assert plan.private_blocks == 1 and len(plan.shared_pages) == 1
    m.admit_prefix(1, plan)
    assert plan.cow_dst is not None and plan.cow_dst != plan.cow_src
    assert m.tables[1, 0] == m.tables[0, 0]      # shared
    assert int(m.tables[1, 1]) == plan.cow_dst   # forked, private
    m.allocator.assert_writable(plan.cow_dst)
    with pytest.raises(ValueError, match="shared"):
        m.allocator.assert_writable(int(m.tables[1, 0]))
    # the fork source is pinned (slot 0 + index + pin) until the device
    # copy lands, so eviction can never reclaim it mid-fork
    assert m.allocator.refcount(plan.cow_src) == 3
    m.cow_release(plan)
    assert m.allocator.refcount(plan.cow_src) == 2


def test_manager_cow_fork_under_oom():
    """The fork needs a page; with none free and nothing evictable the
    admission fails atomically — tables and refcounts unchanged."""
    m = _mgr(num_pages=3, slots=2, max_seq=16)   # 2 usable pages
    prompt = list(range(8))
    m.admit_prefix(0, m.plan_admit(prompt))      # takes both pages
    m.register_prefix(0, prompt)
    before = {p: m.allocator.refcount(p) for p in m.owned[0]}
    plan = m.plan_admit(list(prompt))
    assert plan.cow_src is not None
    assert not m.can_admit_plan(plan)
    assert m.admit_prefix(1, plan) is None
    assert not m.owned[1]
    assert all(t == TRASH_PAGE for t in m.tables[1])
    assert {p: m.allocator.refcount(p) for p in m.owned[0]} == before


def test_manager_retract_above_shared_boundary():
    """retract_above must never free a page another slot (or the index)
    holds: retraction into a shared region drops only this slot's refs."""
    m = _mgr(num_pages=12, slots=2)
    prompt = list(range(12))                     # 3 aligned pages
    m.admit_prefix(0, m.plan_admit(prompt))
    m.register_prefix(0, prompt)
    plan = m.plan_admit(list(prompt))            # shares 2, forks 1
    m.admit_prefix(1, plan)
    shared_pg = int(m.tables[1, 1])
    assert shared_pg == int(m.tables[0, 1])
    used_before = m.allocator.used
    n = m.retract_above(1, 4)                    # keep block 0 only
    assert n == 2                                # blocks 1 (shared) + 2 (fork)
    assert m.tables[1, 1] == TRASH_PAGE and m.tables[1, 2] == TRASH_PAGE
    assert int(m.tables[0, 1]) == shared_pg      # other slot untouched
    assert m.allocator.refcount(shared_pg) == 2  # slot 0 + index
    assert m.allocator.used == used_before - 1   # only the fork freed


def test_manager_release_keeps_index_pages_then_eviction_reclaims():
    m = _mgr(num_pages=5, slots=1, max_seq=16)   # 4 usable
    prompt = list(range(8))
    m.admit_prefix(0, m.plan_admit(prompt))
    m.register_prefix(0, prompt)
    m.release(0)
    # the index keeps the released prefix alive as reusable cache
    assert m.allocator.used == 2 and m.allocator.free == 2
    assert len(m.index) == 2
    # and the same prompt later re-admits against it with zero prefill
    plan = m.plan_admit(list(prompt))
    assert plan.cached_tokens == 7
    # an unrelated admission needing the whole pool evicts LRU entries
    plan2 = m.plan_admit([99] * 16)
    assert plan2.private_blocks == 4
    assert m.can_admit_plan(plan2)
    assert m.admit_prefix(0, plan2) is not None
    assert m.evictions == 2 and len(m.index) == 0


# ---------------------------------------------------------------------------
# gather_slot: shared pages resolve, truly-unmapped entries poison
# ---------------------------------------------------------------------------

def test_gather_slot_resolves_shared_and_poisons_unmapped():
    L, H, D, ps, P = 2, 2, 8, 4, 10
    m = PagedCacheManager(P, ps, 2, 16, prefix_index=PrefixIndex(ps))
    prompt = list(range(10))                     # 2 full pages + partial
    m.admit_prefix(0, m.plan_admit(prompt))
    pool = {"k_pages": jnp.zeros((L, P, ps, H, D)),
            "v_pages": jnp.zeros((L, P, ps, H, D))}
    pcache = {
        "k": jax.random.normal(jax.random.PRNGKey(1), (L, 1, 12, H, D)),
        "v": jax.random.normal(jax.random.PRNGKey(2), (L, 1, 12, H, D))}
    # protocol order matters: scatter targets must be private, so the
    # prefill lands before the prefix is published / shared
    pool = scatter_prefill(pool, pcache,
                           jnp.asarray(m.prefill_page_idx(0, 3))[None, :])
    m.register_prefix(0, prompt)
    m.admit_prefix(1, m.plan_admit(list(prompt)))
    v0 = gather_slot(pool, jnp.asarray(m.tables[0]), ps)
    v1 = gather_slot(pool, jnp.asarray(m.tables[1]), ps)
    # the shared prefix resolves identically through both tables
    np.testing.assert_array_equal(np.asarray(v0["k"][:, :8]),
                                  np.asarray(v1["k"][:, :8]))
    np.testing.assert_array_equal(np.asarray(v0["k"][:, :8]),
                                  np.asarray(pcache["k"][:, 0, :8]))
    # mapped-but-stale rows are real data; unmapped blocks poison to NaN
    assert not np.isnan(np.asarray(v0["k"][:, :12])).any()
    assert np.isnan(np.asarray(v0["k"][:, 16:])).all()
    assert np.isnan(np.asarray(v1["v"][:, 16:])).all()


# ---------------------------------------------------------------------------
# engine: shared == unshared, bit-identical
# ---------------------------------------------------------------------------

def test_shared_matches_unshared_greedy():
    reqs = _shared_reqs(4)
    want = _serve(_engine(), reqs)
    eng = _engine(prefix_sharing=True)
    got = _serve(eng, reqs)
    assert got == want
    p = eng.last_pool_stats
    assert p.sharing_ratio > 1.0
    assert p.cached_prefix_tokens > 0
    assert p.cow_forks >= 1                      # the aligned duplicate


def test_shared_matches_unshared_across_admission_orders():
    reqs = _shared_reqs(5, seed=11)
    want = _serve(_engine(batch_slots=3), reqs)
    rng = np.random.default_rng(0)
    for trial in range(3):
        order = list(reqs)
        rng.shuffle(order)
        got = _serve(_engine(batch_slots=3, prefix_sharing=True), order)
        assert got == want, f"trial {trial}"


def test_shared_forced_preemption_matches_unshared():
    """A pool too small for the working set forces preempt-and-requeue;
    prefix sharing must stay bit-identical (and the resumed request
    re-matches its own published prefix)."""
    reqs = [Request(uid=0, prompt=list(range(1, 17)), max_new_tokens=12),
            Request(uid=1, prompt=list(range(1, 17)) + [77, 78],
                    max_new_tokens=12)]
    want = _serve(_engine(), reqs)
    eng = _engine(prefix_sharing=True, num_pages=6)
    got = _serve(eng, reqs)
    assert got == want
    assert eng.preemptions >= 1


def test_shared_temperature_sampling_matches_unshared():
    reqs = _shared_reqs(4, seed=5, max_new=5)
    want = _serve(_engine(temperature=0.7), reqs)
    got = _serve(_engine(temperature=0.7, prefix_sharing=True), reqs)
    assert got == want


def test_shared_with_spec_decode_matches_unshared():
    """Speculative windows ride shared prefixes: rollback retracts only
    private window pages, outputs stay bit-identical."""
    reqs = _shared_reqs(3, seed=7, max_new=6)
    want = _serve(_engine(), reqs)
    got_spec = _serve(_engine(prefix_sharing=True, spec_k=2,
                              draft="self:1"), reqs)
    assert got_spec == want


def test_shared_page_bound():
    """The acceptance bound: N requests over a page-aligned common prefix
    allocate at most prefix_pages + N * suffix_pages physical pages."""
    ps = 8
    n = 4
    reqs = _shared_reqs(n, prefix_len=16, suf_lo=1, suf_hi=8, max_new=5,
                        dup_aligned=False)
    eng_off = _engine(batch_slots=2)
    eng_on = _engine(batch_slots=2, prefix_sharing=True)
    want = _serve(eng_off, reqs)
    got = _serve(eng_on, reqs)
    assert got == want
    prefix_pages = 16 // ps
    suffix_pages = sum(
        -(-(len(r.prompt) + r.max_new_tokens - 1) // ps) - prefix_pages
        for r in reqs)
    p_on, p_off = eng_on.last_pool_stats, eng_off.last_pool_stats
    assert p_on.peak_used_pages <= prefix_pages + suffix_pages
    assert p_on.peak_used_pages < p_off.peak_used_pages
    # every request after the first served its whole prefix from cache
    cached = [eng_on.last_stats[r.uid]["cached_prefix_tokens"]
              for r in reqs]
    assert cached[0] == 0 and all(c == 16 for c in cached[1:])


def test_shared_stats_logical_vs_physical():
    reqs = _shared_reqs(4, seed=9)
    eng = _engine(prefix_sharing=True)
    results = _serve(eng, reqs)
    p = eng.last_pool_stats
    assert p.logical_tokens == p.logical_pages * p.page_size
    assert p.physical_tokens == p.physical_pages * p.page_size
    assert p.peak_logical_pages >= p.peak_used_pages
    assert p.sharing_ratio >= 1.0
    # all slots released: remaining pages are exactly the index cache
    assert p.logical_pages == 0 and p.physical_pages == 0
    assert p.used_pages == p.index_pages > 0
    for uid in results:
        assert "cached_prefix_tokens" in eng.last_stats[uid]


def test_engine_rejects_sharing_misconfiguration():
    cfg, model, params = _model()
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(model, params, max_seq=32, batch_slots=2,
                    prefix_sharing=True)
    cfg2, model2, params2 = _model("olmoe-1b-7b")
    with pytest.raises(ValueError, match="family"):
        ServeEngine(model2, params2, max_seq=32, batch_slots=2,
                    cache_layout="paged", prefix_sharing=True)


# ---------------------------------------------------------------------------
# property test: sharing on == off over random overlapping schedules
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @settings(max_examples=6, deadline=None)
    @given(data=st.data())
    def test_property_sharing_equals_unshared(data):
        """Random admit/decode/release/preempt schedules with overlapping
        prompts (a small pool of prefixes, random depths and suffixes):
        prefix sharing must be output-invisible."""
        cfg, _, _ = _model()
        rng = np.random.default_rng(
            data.draw(st.integers(0, 2 ** 16), label="seed"))
        base = rng.integers(0, cfg.vocab, 24).tolist()
        n = data.draw(st.integers(3, 6), label="n_requests")
        reqs = []
        for i in range(n):
            depth = data.draw(st.integers(0, 20), label=f"depth{i}")
            extra = data.draw(st.integers(1, 6), label=f"extra{i}")
            mnew = data.draw(st.integers(1, 7), label=f"mnew{i}")
            prompt = base[:depth] + rng.integers(
                0, cfg.vocab, extra).tolist()
            reqs.append(Request(uid=i, prompt=prompt, max_new_tokens=mnew))
        slots = data.draw(st.integers(1, 3), label="slots")
        # pool from barely-fits (forcing preemption + eviction) upward
        longest = max(min(len(r.prompt) + r.max_new_tokens - 1, 48)
                      for r in reqs)
        min_pages = -(-longest // 8)
        num_pages = data.draw(st.integers(min_pages + 1, 19), label="pages")
        want = _serve(_engine(batch_slots=slots, num_pages=num_pages), reqs)
        got = _serve(_engine(batch_slots=slots, num_pages=num_pages,
                             prefix_sharing=True), reqs)
        assert got == want
