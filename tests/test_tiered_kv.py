"""Tiered KV memory: int8 quantized pages (quantize-on-write, fused
dequant gather, kernel-vs-SW parity), host-swap preemption (round-trip
bit-exactness, swap == requeue greedy parity incl. fault recovery),
pluggable prefix-index eviction policies + min_cached_tokens, roofline
int8-width gather accounting, quantized-pool audit, and empty-session
stats regressions."""

import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import reduced_config
from repro.models.attention import (
    paged_decode_attention,
    paged_verify_attention,
)
from repro.models.lm import Model
from repro.roofline.jaxpr_cost import trace_cost
from repro.serve.audit import audit_pool
from repro.serve.engine import Request, ServeEngine
from repro.serve.faults import Fault, FaultSchedule
from repro.serve.kv_cache import (
    TRASH_PAGE,
    PagedCacheManager,
    dequantize_kv,
    gather_slot,
    pool_is_quantized,
    quantize_kv_rows,
    resolve_kv_dtype,
    scatter_prefill,
    swap_in_pages,
    swap_out_pages,
)
from repro.serve.prefix_index import EVICT_POLICIES, PrefixIndex

_CACHE = {}


def _model(arch="qwen2-1.5b"):
    if arch not in _CACHE:
        cfg = reduced_config(arch)
        model = Model(cfg, compute_dtype=jnp.float32)
        params = model.init(jax.random.PRNGKey(1))
        _CACHE[arch] = (cfg, model, params)
    return _CACHE[arch]


def _engine(arch="qwen2-1.5b", **kw):
    cfg, model, params = _model(arch)
    kw = {"max_seq": 48, "batch_slots": 2, "temperature": 0.0, "seed": 0,
          "cache_layout": "paged", "page_size": 8, **kw}
    return ServeEngine(model, params, **kw)


def _reqs(n, prompt_len=12, max_new=5, seed=3):
    cfg, _, _ = _model()
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        prompt_len + i).tolist(),
                    max_new_tokens=max_new)
            for i in range(n)]


def _serve(engine, reqs):
    return engine.serve(copy.deepcopy(reqs))


def _quantized_pool(rng, n_layers=2, n_pages=7, page_size=4, hkv=2, d=8):
    """Random float K/V quantized into an int8 pool (+ the float source)."""
    kv = rng.normal(size=(2, n_layers, n_pages, page_size, hkv, d)) \
        .astype(np.float32)
    kq, ks = quantize_kv_rows(jnp.asarray(kv[0]))
    vq, vs = quantize_kv_rows(jnp.asarray(kv[1]))
    return {"k_pages": kq, "v_pages": vq, "k_scales": ks, "v_scales": vs}, kv


# ---------------------------------------------------------------------------
# quantization primitives
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(3, 5, 2, 8)) * 4.0, jnp.float32)
    q, s = quantize_kv_rows(x)
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32
    assert s.shape == x.shape[:-2]
    back = dequantize_kv(q, s)
    # symmetric absmax: per-element error <= scale/2 = absmax/254
    bound = np.asarray(jnp.max(jnp.abs(x), axis=(-2, -1))) / 254.0
    err = np.asarray(jnp.max(jnp.abs(back - x), axis=(-2, -1)))
    assert np.all(err <= bound + 1e-7)


def test_quantize_zero_rows_exact():
    x = jnp.zeros((2, 3, 2, 4), jnp.float32)
    q, s = quantize_kv_rows(x)
    assert np.all(np.asarray(s) == 0.0)
    assert np.all(np.asarray(dequantize_kv(q, s)) == 0.0)


def test_quantize_row_independence():
    """The swap/replay contract: a row's stored bytes depend only on that
    row, never on its neighbors."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(2, 6, 2, 8)), jnp.float32)
    q_all, s_all = quantize_kv_rows(x)
    q_one, s_one = quantize_kv_rows(x[:, 2:3])
    np.testing.assert_array_equal(np.asarray(q_all[:, 2:3]),
                                  np.asarray(q_one))
    np.testing.assert_array_equal(np.asarray(s_all[:, 2:3]),
                                  np.asarray(s_one))


def test_resolve_kv_dtype():
    assert resolve_kv_dtype(None, jnp.float32) == (jnp.dtype(jnp.float32),
                                                   False)
    assert resolve_kv_dtype("auto", jnp.bfloat16) == (
        jnp.dtype(jnp.bfloat16), False)
    assert resolve_kv_dtype("bf16", jnp.float32) == (
        jnp.dtype(jnp.bfloat16), False)
    assert resolve_kv_dtype("int8", jnp.float32) == (jnp.dtype(jnp.int8),
                                                     True)
    with pytest.raises(ValueError):
        resolve_kv_dtype("fp4", jnp.float32)


# ---------------------------------------------------------------------------
# quantized scatter/gather (dequant debug view + NaN poison)
# ---------------------------------------------------------------------------

def test_gather_slot_quantized_matches_dense():
    L, B, S, H, D, ps, P = 2, 2, 10, 2, 8, 4, 12
    m = PagedCacheManager(num_pages=P, page_size=ps, slots=B, max_seq=16,
                          kv_dtype="int8")
    lens = [10, 7]
    for s, ln in enumerate(lens):
        m.admit(s, ln)
    pool = {"k_pages": jnp.zeros((L, P, ps, H, D), jnp.int8),
            "v_pages": jnp.zeros((L, P, ps, H, D), jnp.int8),
            "k_scales": jnp.zeros((L, P, ps), jnp.float32),
            "v_scales": jnp.zeros((L, P, ps), jnp.float32)}
    assert pool_is_quantized(pool)
    rng = np.random.default_rng(2)
    pcache = {"k": jnp.asarray(rng.normal(size=(L, B, S, H, D)),
                               jnp.float32),
              "v": jnp.asarray(rng.normal(size=(L, B, S, H, D)),
                               jnp.float32)}
    nb = -(-S // ps)
    page_idx = jnp.asarray(np.stack([m.prefill_page_idx(s, nb)
                                     for s in range(B)]))
    pool = scatter_prefill(pool, pcache, page_idx)
    for s, ln in enumerate(lens):
        view = gather_slot(pool, jnp.asarray(m.tables[s]), ps)
        for name in ("k", "v"):
            got = np.asarray(view[name][:, :ln])
            want = np.asarray(pcache[name][:, s, :ln])
            assert got.dtype == np.float32
            # dequantized view is within the per-row absmax/254 bound
            bound = np.abs(want).max(axis=(-2, -1), keepdims=True) / 254.0
            assert np.all(np.abs(got - want) <= bound + 1e-6)
            # unmapped blocks come back NaN-poisoned even though the
            # stored values are int8 (the view is float)
            n_mapped = -(-ln // ps)
            tail = np.asarray(view[name][:, n_mapped * ps:])
            assert tail.size and np.all(np.isnan(tail))


# ---------------------------------------------------------------------------
# quantized kernel-vs-SW attention parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ["decode", "verify"])
def test_quantized_kernel_vs_sw_parity(family):
    rng = np.random.default_rng(4)
    hq, hkv, d, ps, nb, b = 4, 2, 16, 8, 3, 2
    pool, _ = _quantized_pool(rng, n_layers=1, n_pages=1 + b * nb,
                              page_size=ps, hkv=hkv, d=d)
    tables = jnp.asarray(np.arange(1, 1 + b * nb).reshape(b, nb), jnp.int32)
    pos = jnp.asarray([ps + 3, 2 * ps + 1], jnp.int32)
    t_w = 1 if family == "decode" else 3
    q = jnp.asarray(rng.normal(size=(b, t_w, hq, d)), jnp.float32)
    fn = (paged_decode_attention if family == "decode"
          else paged_verify_attention)
    outs = {be: np.asarray(fn(q, pool["k_pages"][0], pool["v_pages"][0],
                              tables, pos, k_scales=pool["k_scales"][0],
                              v_scales=pool["v_scales"][0], backend=be))
            for be in ("kernel", "jnp")}
    np.testing.assert_allclose(outs["kernel"], outs["jnp"],
                               atol=2e-5, rtol=1e-4)


def test_quantized_attention_matches_dequantized_reference():
    """Fused dequant in the gather == dequantize-then-attend: the scale
    operand changes where the multiply happens, never the math."""
    rng = np.random.default_rng(5)
    hq, hkv, d, ps, nb, b = 2, 1, 8, 4, 2, 1
    pool, _ = _quantized_pool(rng, n_layers=1, n_pages=1 + nb,
                              page_size=ps, hkv=hkv, d=d)
    tables = jnp.asarray([[1, 2]], jnp.int32)
    pos = jnp.asarray([ps + 1], jnp.int32)
    q = jnp.asarray(rng.normal(size=(b, 1, hq, d)), jnp.float32)
    got = np.asarray(paged_decode_attention(
        q, pool["k_pages"][0], pool["v_pages"][0], tables, pos,
        k_scales=pool["k_scales"][0], v_scales=pool["v_scales"][0],
        backend="jnp"))
    k_f = dequantize_kv(pool["k_pages"][0], pool["k_scales"][0])
    v_f = dequantize_kv(pool["v_pages"][0], pool["v_scales"][0])
    want = np.asarray(paged_decode_attention(q, k_f, v_f, tables, pos,
                                             backend="jnp"))
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# roofline: quantized gathers are charged at int8 width
# ---------------------------------------------------------------------------

def test_roofline_charges_int8_gather_width():
    hq, hkv, d, ps, nb, b = 4, 2, 16, 8, 4, 2
    n_pages = 1 + b * nb
    tables = jax.ShapeDtypeStruct((b, nb), jnp.int32)
    pos = jax.ShapeDtypeStruct((b,), jnp.int32)
    q = jax.ShapeDtypeStruct((b, 1, hq, d), jnp.float32)
    val = lambda dt: jax.ShapeDtypeStruct((n_pages, ps, hkv, d), dt)
    sc = jax.ShapeDtypeStruct((n_pages, ps), jnp.float32)

    def run_f32(q, kp, vp, tables, pos):
        return paged_decode_attention(q, kp, vp, tables, pos,
                                      backend="jnp")

    def run_q(q, kp, vp, ks, vs, tables, pos):
        return paged_decode_attention(q, kp, vp, tables, pos, k_scales=ks,
                                      v_scales=vs, backend="jnp")

    # the page gather itself is charged at storage width: the int8 read
    # (plus its int8 result) costs 1/4 of f32, 1/2 of bf16 — the ~2x
    # bandwidth claim of the ISSUE, seen directly by the cost walker
    def bare_gather(kp, tables):
        return jnp.take(kp, tables.reshape(-1), axis=0)

    g_f32 = trace_cost(bare_gather, val(jnp.float32),
                       tables)["bytes_total"]
    g_bf16 = trace_cost(bare_gather, val(jnp.bfloat16),
                        tables)["bytes_total"]
    g_q = trace_cost(bare_gather, val(jnp.int8), tables)["bytes_total"]
    assert 3.5 < g_f32 / g_q < 4.5, g_f32 / g_q
    assert 1.8 < g_bf16 / g_q < 2.2, g_bf16 / g_q

    # end to end the quantized step still reads materially fewer bytes,
    # even with the dtype-independent softmax traffic riding along
    bytes_f32 = trace_cost(run_f32, q, val(jnp.float32), val(jnp.float32),
                           tables, pos)["bytes_total"]
    bytes_q = trace_cost(run_q, q, val(jnp.int8), val(jnp.int8), sc, sc,
                         tables, pos)["bytes_total"]
    assert bytes_f32 / bytes_q > 1.5, bytes_f32 / bytes_q


# ---------------------------------------------------------------------------
# host-swap tier
# ---------------------------------------------------------------------------

def test_swap_pages_roundtrip_bit_exact():
    rng = np.random.default_rng(7)
    pool, _ = _quantized_pool(rng)
    before = {n: np.asarray(v).copy() for n, v in pool.items()}
    host = swap_out_pages(pool, np.asarray([1, 4, 5]))
    assert set(host) == set(pool)
    # scatter back into *different* pages: contents are placement-free
    pool = swap_in_pages(pool, host, jnp.asarray([2, 3, 6], jnp.int32))
    after = {n: np.asarray(v) for n, v in pool.items()}
    for name in before:
        np.testing.assert_array_equal(after[name][:, [2, 3, 6]],
                                      before[name][:, [1, 4, 5]])


def test_manager_swap_out_admit_roundtrip():
    mgr = PagedCacheManager(8, 4, 2, 16, kv_dtype="int8")
    pages = mgr.admit(0, 6)
    assert pages is not None and len(pages) == 2
    rng = np.random.default_rng(8)
    pool, _ = _quantized_pool(rng, n_layers=1, n_pages=8, page_size=4)
    handle = mgr.swap_out(0, pool, 6)
    assert handle.n_blocks == 2 and handle.n_tokens == 6
    assert handle.nbytes == sum(a.nbytes for a in handle.data.values())
    # slot released: pages back in the pool, stats counted
    assert mgr.allocator.free == 7
    assert mgr.stats().swap_outs == 1
    got = mgr.admit_swapped(1, handle)
    assert got is not None and len(got) == 2
    assert mgr.stats().swap_ins == 1
    mgr.audit().raise_if_failed()


def test_admit_swapped_all_or_nothing():
    mgr = PagedCacheManager(4, 4, 2, 16, kv_dtype="int8")
    mgr.admit(0, 6)                              # 2 of 3 usable pages
    rng = np.random.default_rng(9)
    pool, _ = _quantized_pool(rng, n_layers=1, n_pages=4, page_size=4)
    handle = mgr.swap_out(0, pool, 6)
    assert mgr.admit(0, 9) is not None           # re-take all 3 pages
    assert mgr.admit_swapped(1, handle) is None  # needs 2, none free
    mgr.audit().raise_if_failed()


@pytest.mark.parametrize("preempt", ["swap", "auto"])
def test_swap_preemption_matches_requeue(preempt):
    """Forced preemption on a tiny pool: swap-tier resume must produce the
    same greedy tokens as recompute-requeue, int8 pool included."""
    reqs = [Request(uid=0, prompt=list(range(1, 17)), max_new_tokens=16),
            Request(uid=1, prompt=list(range(40, 56)), max_new_tokens=16)]
    base = _engine(num_pages=6, kv_dtype="int8", preempt="requeue",
                   audit=True)
    want = _serve(base, reqs)
    assert base.preemptions > 0
    eng = _engine(num_pages=6, kv_dtype="int8", preempt=preempt,
                  audit=True)
    got = _serve(eng, reqs)
    assert got == want
    if preempt == "swap":
        assert eng.last_pool_stats.swap_outs > 0
        assert eng.last_pool_stats.swap_ins > 0
        assert eng.last_pool_stats.swapped_out_bytes > 0


def test_swap_survives_kernel_fault_recovery():
    """A handle taken before a mid-serve kernel failure restores into the
    rebuilt pool: it records contents, not page numbers."""
    reqs = [Request(uid=0, prompt=list(range(1, 17)), max_new_tokens=16),
            Request(uid=1, prompt=list(range(40, 56)), max_new_tokens=16)]
    want = _serve(_engine(num_pages=6, kv_dtype="int8"), reqs)
    eng = _engine(num_pages=6, kv_dtype="int8", preempt="swap", audit=True)
    got = eng.serve(copy.deepcopy(reqs),
                    faults=FaultSchedule([Fault("kernel", step=4)]))
    assert got == want


# ---------------------------------------------------------------------------
# eviction policies + min_cached_tokens
# ---------------------------------------------------------------------------

def test_prefix_index_validation():
    with pytest.raises(ValueError):
        PrefixIndex(4, policy="mru")
    with pytest.raises(ValueError):
        PrefixIndex(4, min_cached_tokens=-1)
    assert set(EVICT_POLICIES) == {"lru", "lfu", "deepest"}


def test_min_cached_tokens_rejects_short_prompts():
    ix = PrefixIndex(4, min_cached_tokens=8)
    assert ix.insert([1, 2, 3, 4, 5], [10]) == []     # 1 full page < 8
    assert len(ix) == 0 and ix.rejected_inserts == 1
    assert ix.match([1, 2, 3, 4]) == []
    # two full pages meet the threshold
    assert ix.insert([1, 2, 3, 4, 5, 6, 7, 8], [10, 11]) == [10, 11]
    assert len(ix) == 2 and ix.rejected_inserts == 1


def test_lfu_evicts_least_hit_leaf():
    ix = PrefixIndex(2, policy="lfu")
    ix.insert([1, 1], [3])
    ix.insert([2, 2], [4])
    ix.match([1, 1])          # page 3: 1 hit
    ix.match([1, 1])          # page 3: 2 hits
    ix.match([2, 2])          # page 4: 1 hit, more recent
    assert ix.evict(1, lambda p: True) == [4]


def test_deepest_evicts_long_tails_first():
    # the shallow leaf is the LRU victim, but deepest prunes the tail
    ix = PrefixIndex(2, policy="deepest")
    ix.insert([9, 9], [8])                     # oldest leaf, depth 1
    ix.insert([1, 1, 2, 2, 3, 3], [5, 6, 7])   # newest, depth-3 chain
    assert ix.evict(1, lambda p: True) == [7]
    lru = PrefixIndex(2, policy="lru")
    lru.insert([9, 9], [8])
    lru.insert([1, 1, 2, 2, 3, 3], [5, 6, 7])
    assert lru.evict(1, lambda p: True) == [8]


def test_engine_eviction_policies_greedy_identical():
    """Policies change which pages linger, never the computed tokens."""
    cfg, _, _ = _model()
    rng = np.random.default_rng(11)
    prefixes = [rng.integers(0, cfg.vocab, 16).tolist() for _ in range(3)]
    reqs = [Request(uid=i,
                    prompt=prefixes[i % 3]
                    + rng.integers(0, cfg.vocab, 3 + i).tolist(),
                    max_new_tokens=4)
            for i in range(6)]
    outs = {}
    for policy in EVICT_POLICIES:
        eng = _engine(num_pages=9, prefix_sharing=True,
                      evict_policy=policy, min_cached_tokens=8, audit=True)
        outs[policy] = _serve(eng, reqs)
        assert eng.last_pool_stats.audit_ok
    assert outs["lru"] == outs["lfu"] == outs["deepest"]


# ---------------------------------------------------------------------------
# quantized-pool audit
# ---------------------------------------------------------------------------

def test_audit_pool_passes_consistent_quantized_pool():
    mgr = PagedCacheManager(8, 4, 2, 16, kv_dtype="int8")
    mgr.admit(0, 6)
    pool, _ = _quantized_pool(np.random.default_rng(12), n_layers=1,
                              n_pages=8, page_size=4)
    assert audit_pool(mgr, pool).ok
    assert audit_pool(mgr, pool, check_values=True).ok


def test_audit_pool_catches_metadata_corruption():
    mgr = PagedCacheManager(8, 4, 2, 16, kv_dtype="int8")
    mgr.admit(0, 6)
    pool, _ = _quantized_pool(np.random.default_rng(13), n_layers=1,
                              n_pages=8, page_size=4)
    # manager says int8, pool lost its scale leaves
    bare = {n: pool[n] for n in ("k_pages", "v_pages")}
    assert not audit_pool(mgr, bare).ok
    # scale leaf with the wrong shape
    assert not audit_pool(mgr, dict(pool,
                                    k_scales=pool["k_scales"][:, :4])).ok
    # scale leaf with the wrong dtype
    assert not audit_pool(
        mgr, dict(pool, v_scales=pool["v_scales"].astype(jnp.float16))).ok
    # NaN scale on a mapped page: structural pass, value sweep fails
    mapped = mgr.owned[0][0]
    poisoned = dict(pool, k_scales=pool["k_scales"]
                    .at[:, mapped].set(jnp.nan))
    assert audit_pool(mgr, poisoned).ok
    assert not audit_pool(mgr, poisoned, check_values=True).ok


def test_audit_pool_float_pool_vs_int8_manager():
    mgr = PagedCacheManager(8, 4, 2, 16)         # kv_dtype None
    pool, _ = _quantized_pool(np.random.default_rng(14), n_layers=1,
                              n_pages=8, page_size=4)
    assert not audit_pool(mgr, pool).ok          # quantized pool, f32 mgr


# ---------------------------------------------------------------------------
# engine integration: int8 end-to-end, ctor validation, empty sessions
# ---------------------------------------------------------------------------

def test_int8_engine_greedy_matches_dense():
    reqs = _reqs(4, max_new=5)
    cfg, model, params = _model()
    dense = ServeEngine(model, params, max_seq=48, batch_slots=2,
                        temperature=0.0, seed=0)
    want = _serve(dense, reqs)
    for kv in ("bf16", "int8"):
        eng = _engine(num_pages=13, kv_dtype=kv, audit=True)
        assert _serve(eng, reqs) == want
        assert eng.last_pool_stats.kv_dtype == kv
        assert eng.last_pool_stats.audit_ok


def test_int8_pool_bytes_near_half_bf16():
    _, model, _ = _model()

    def nbytes(kv):
        shapes = jax.eval_shape(lambda: model.init_cache(
            2, 48, layout="paged", page_size=8, num_pages=13, kv_dtype=kv))
        return sum(int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
                   for l in jax.tree.leaves(shapes)
                   if l.dtype != jnp.int32)      # exclude block tables

    ratio = nbytes("bf16") / nbytes("int8")
    assert 1.8 <= ratio <= 2.0, ratio


def test_engine_ctor_validation():
    _, model, params = _model()
    with pytest.raises(ValueError):
        _engine(kv_dtype="fp8")
    with pytest.raises(ValueError):
        ServeEngine(model, params, max_seq=48, batch_slots=2,
                    kv_dtype="int8")             # dense layout
    with pytest.raises(ValueError):
        _engine(preempt="steal")
    with pytest.raises(ValueError):
        ServeEngine(model, params, max_seq=48, batch_slots=2,
                    preempt="swap")              # dense layout
    with pytest.raises(ValueError):
        _engine(evict_policy="mru")
    with pytest.raises(ValueError):
        _engine(min_cached_tokens=-1)


@pytest.mark.parametrize("layout", ["dense", "paged", "shared", "int8"])
def test_empty_session_stats_defined(layout):
    """serve([]) regression: percentile helpers and sharing ratio must
    come back defined (None-filled / 1.0), never raise or NaN."""
    _, model, params = _model()
    kw = {"max_seq": 48, "batch_slots": 2, "temperature": 0.0, "seed": 0}
    if layout != "dense":
        kw.update(cache_layout="paged", page_size=8)
    if layout == "shared":
        kw.update(prefix_sharing=True)
    if layout == "int8":
        kw.update(kv_dtype="int8")
    eng = ServeEngine(model, params, **kw)
    assert eng.serve([]) == {}
    sla = eng.last_stats["sla"]
    assert sla["requests"] == 0 and sla["statuses"] == {}
    assert sla["ok_tokens"] == 0 and np.isfinite(sla["goodput_tok_s"])
    for key in ("ttft_ms", "tbt_ms"):
        assert sla[key]["n"] == 0
        assert sla[key]["p50"] is None and sla[key]["p99"] is None
    if layout != "dense":
        p = eng.last_pool_stats
        assert p.sharing_ratio == 1.0 and np.isfinite(p.sharing_ratio)
        assert p.audit_ok and p.swap_outs == 0 and p.swap_ins == 0
