"""Per-architecture smoke tests: reduced config, one forward + one train
step + one decode step on CPU; asserts shapes and finiteness."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import list_archs, reduced_config
from repro.models.lm import Model

B, S = 2, 32


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab)}
    if cfg.n_frontend_tokens:
        batch["frontend_embeds"] = jax.random.normal(
            ks[1], (B, cfg.n_frontend_tokens, cfg.d_model)) * 0.02
    return batch


@pytest.fixture(scope="module")
def built():
    out = {}
    for name in list_archs():
        cfg = reduced_config(name)
        model = Model(cfg, remat=False, compute_dtype=jnp.float32)
        params = model.init(jax.random.PRNGKey(0))
        out[name] = (cfg, model, params)
    return out


@pytest.mark.parametrize("name", list_archs())
def test_forward_shapes_and_finite(built, name):
    cfg, model, params = built[name]
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits = jax.jit(model.forward)(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{name}: non-finite logits"


@pytest.mark.parametrize("name", list_archs())
def test_train_step_no_nans(built, name):
    cfg, model, params = built[name]
    batch = _batch(cfg, jax.random.PRNGKey(2))

    def loss_fn(p):
        logits = model.forward(p, batch)
        labels = jnp.roll(batch["tokens"], -1, axis=1)
        lp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.mean(jnp.take_along_axis(lp, labels[..., None], -1))

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss)), f"{name}: loss NaN"
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat), \
        f"{name}: grad NaN"
    # gradients must reach the embedding (whole graph is connected)
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in flat)
    assert gnorm > 0, f"{name}: zero gradients"


@pytest.mark.parametrize("name", list_archs())
def test_decode_step(built, name):
    cfg, model, params = built[name]
    cache = model.init_cache(B, max_seq=64)
    tok = jnp.array([1, 2], dtype=jnp.int32)
    pos = jnp.array([0, 0], dtype=jnp.int32)
    if cfg.family == "encdec":
        # prime cross-attention caches from a stub encoder pass
        enc = jax.random.normal(jax.random.PRNGKey(3),
                                (B, cfg.n_frontend_tokens, cfg.d_model)) * 0.02
        from repro.models.attention import encode_cross_kv
        enc_out = model._scan_encoder(params, enc.astype(model.compute_dtype))
        ck, cv = jax.vmap(
            lambda p: encode_cross_kv(p["cross"], enc_out, cfg)
        )(params["layers"])
        cache["cross_k"], cache["cross_v"] = ck, cv
    step = jax.jit(model.decode_step)
    logits, cache = step(params, cache, tok, pos)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{name}: decode NaN"
    logits2, cache = step(params, cache, tok, pos + 1)
    assert bool(jnp.all(jnp.isfinite(logits2)))


@pytest.mark.parametrize("name", ["rwkv6-7b", "zamba2-2.7b"])
def test_recurrent_decode_matches_forward(built, name):
    """Teacher-forced decode must reproduce the parallel forward logits —
    the O(1)-state decode path is the long_500k story, so its equivalence
    with the scan-parallel path is load-bearing."""
    cfg, model, params = built[name]
    batch = _batch(cfg, jax.random.PRNGKey(4))
    toks = batch["tokens"]
    ref = model.forward(params, batch)           # (B, S, V)
    cache = model.init_cache(B, max_seq=64)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(8):
        logits, cache = step(params, cache, toks[:, t],
                             jnp.full((B,), t, jnp.int32))
        outs.append(logits)
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref[:, :8]),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("name", ["qwen2-1.5b", "minicpm3-4b"])
def test_attention_decode_matches_forward(built, name):
    """KV-cache (incl. MLA absorbed-latent) decode == parallel forward."""
    cfg, model, params = built[name]
    batch = _batch(cfg, jax.random.PRNGKey(5))
    toks = batch["tokens"]
    ref = model.forward(params, batch)
    cache = model.init_cache(B, max_seq=64)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(8):
        logits, cache = step(params, cache, toks[:, t],
                             jnp.full((B,), t, jnp.int32))
        outs.append(logits)
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref[:, :8]),
                               rtol=2e-3, atol=2e-3)


def test_chunked_attention_matches_full():
    cfg = reduced_config("qwen2-1.5b")
    m_full = Model(cfg, remat=False, compute_dtype=jnp.float32)
    m_chunk = Model(cfg, remat=False, compute_dtype=jnp.float32, chunk_q=8)
    params = m_full.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(6))
    np.testing.assert_allclose(
        np.asarray(m_full.forward(params, batch)),
        np.asarray(m_chunk.forward(params, batch)), rtol=1e-4, atol=1e-4)


def test_sw_backend_model_matches_hw():
    """The paper's knob at model level: norms via SW (serialized) path must
    produce the same logits as the HW path."""
    from repro.models.layers import WarpFeatureConfig

    cfg = reduced_config("qwen2-1.5b")
    m_hw = Model(cfg, remat=False, compute_dtype=jnp.float32,
                 wf=WarpFeatureConfig(reduction_backend="hw", warp_size=32))
    m_sw = Model(cfg, remat=False, compute_dtype=jnp.float32,
                 wf=WarpFeatureConfig(reduction_backend="sw", warp_size=32))
    params = m_hw.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(7))
    np.testing.assert_allclose(np.asarray(m_hw.forward(params, batch)),
                               np.asarray(m_sw.forward(params, batch)),
                               rtol=2e-4, atol=2e-4)
