"""Overlapped round pipeline: dispatch/commit decode with host work in
the gap must be a pure latency optimization.

The contract under test: ``pipeline=True`` (async dispatch, commit at
the next round's barrier, D2H swap copies deferred) returns bit-identical
outputs to ``pipeline=False`` (today's serial round) for every request —
across preemption, injected NaN/kernel faults with recovery, speculative
decoding, chunked prefill, and a disaggregated 2-replica cluster.  The
only visible difference allowed is one extra trailing round per session
(the last step's commit)."""

import copy
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import reduced_config
from repro.models.lm import Model
from repro.serve import (
    STATUS_OK,
    Fault,
    FaultSchedule,
    Request,
    ServeEngine,
    make_cluster,
)
from repro.serve.calibrate import (
    DEFAULT_COST_MODEL,
    CostModel,
    calibrate,
)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as hyp_st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

_CACHE = {}


def _model(arch="qwen2-1.5b"):
    if arch not in _CACHE:
        cfg = reduced_config(arch)
        model = Model(cfg, compute_dtype=jnp.float32)
        params = model.init(jax.random.PRNGKey(1))
        _CACHE[arch] = (cfg, model, params)
    return _CACHE[arch]


_EKW = {"max_seq": 48, "batch_slots": 2, "temperature": 0.0, "seed": 0,
        "cache_layout": "paged", "page_size": 8}


def _engine(**kw):
    cfg, model, params = _model()
    return ServeEngine(model, params, **{**_EKW, **kw})


def _reqs(n, seed=3, plo=3, phi=12, mlo=2, mhi=7, **fields):
    cfg, _, _ = _model()
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(
                        0, cfg.vocab,
                        size=int(rng.integers(plo, phi))).tolist(),
                    max_new_tokens=int(rng.integers(mlo, mhi)), **fields)
            for i in range(n)]


def _fresh(reqs):
    return [dataclasses.replace(r, generated=None) for r in reqs]


def _both(reqs, faults=None, **kw):
    """Serve the same batch serial and pipelined; return both engines'
    (results, stats)."""
    out = {}
    for pipeline in (False, True):
        eng = _engine(pipeline=pipeline, **kw)
        fs = copy.deepcopy(faults) if faults is not None else None
        res = eng.serve(_fresh(reqs), faults=fs)
        out[pipeline] = (res, eng.last_stats)
    return out[False], out[True]


# ------------------------------------------------------------ plain parity
@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_pipeline_parity(temperature):
    (ref, _), (out, _) = _both(_reqs(6), temperature=temperature)
    assert out == ref


def test_pipeline_trailing_round_only():
    """The pipelined session runs exactly one extra round (the trailing
    commit of the final step)."""
    (_, s_ref), (_, s_pipe) = _both(_reqs(5))
    assert (s_pipe["timeseries"]["round"][-1]
            == s_ref["timeseries"]["round"][-1] + 1)


def test_pipeline_parity_under_preemption():
    """A pool too small for the batch forces preempt-and-requeue churn;
    outputs must not move."""
    reqs = _reqs(6, mlo=6, mhi=12)
    for preempt in ("requeue", "swap"):
        (ref, s_ref), (out, s_pipe) = _both(
            reqs, num_pages=4, preempt=preempt)
        assert out == ref
        ref_pre = sum(s_ref[r.uid]["preemptions"] for r in reqs)
        pipe_pre = sum(s_pipe[r.uid]["preemptions"] for r in reqs)
        assert ref_pre == pipe_pre and ref_pre > 0


def test_pipeline_swap_deferred_materialization():
    """Pipelined swap-out defers the D2H copy past the next dispatch;
    the resumed outputs are still bit-identical and every handle drains
    by session end."""
    reqs = _reqs(6, mlo=6, mhi=12)
    (ref, s_ref), (out, s_pipe) = _both(reqs, num_pages=4, preempt="swap")
    assert out == ref
    assert sum(s_pipe[r.uid].get("swap_ins", 0) for r in reqs) > 0


def test_pipeline_parity_under_faults_with_recovery():
    """Injected NaN quarantine + a kernel failure with step-restart
    recovery: the pipelined run discards or drains its pending round
    atomically and replays identically."""
    reqs = _reqs(6, mlo=6, mhi=10)
    fs = FaultSchedule([Fault("nan", step=2, uid=1, span=2),
                        Fault("kernel", step=6)])
    (ref, s_ref), (out, s_pipe) = _both(reqs, faults=fs)
    assert out == ref
    for r in reqs:
        assert s_pipe[r.uid]["status"] == s_ref[r.uid]["status"]
    assert s_pipe[1]["status"] != STATUS_OK  # the quarantined request


def test_pipeline_parity_page_corruption_and_cancel():
    reqs = _reqs(6)
    fs = FaultSchedule([Fault("page_corruption", step=2),
                        Fault("cancel", step=3, uid=2)], seed=9)
    (ref, s_ref), (out, s_pipe) = _both(reqs, faults=fs, audit=True)
    assert out == ref
    for r in reqs:
        assert s_pipe[r.uid]["status"] == s_ref[r.uid]["status"]


def test_pipeline_parity_spec_decode():
    reqs = _reqs(5, mlo=4, mhi=9)
    (ref, _), (out, s_pipe) = _both(reqs, spec_k=4)
    assert out == ref
    assert sum(s_pipe[r.uid].get("spec_tokens", 0) for r in reqs) > 0


def test_pipeline_parity_chunked_prefill():
    reqs = _reqs(5, plo=9, phi=16)
    (ref, _), (out, _) = _both(reqs, prefill_budget=8)
    assert out == ref


def test_pipeline_parity_cluster_disaggregated():
    """2-replica disaggregated fleet with pipelined workers == the
    serial direct engine."""
    cfg, model, params = _model()
    reqs = _reqs(6)
    ref = _engine(pipeline=False).serve(_fresh(reqs))
    c = make_cluster(model, params, replicas=2, disaggregate=True,
                     pipeline=True, **_EKW)
    out = c.serve(_fresh(reqs))
    assert out == ref
    assert c.audit_report.ok


def test_pipeline_timeseries_phases():
    """The pipelined timeseries reports dispatch/commit/overlap phase
    timings and the SLA summary rolls them up."""
    eng = _engine(pipeline=True)
    eng.serve(_fresh(_reqs(4)))
    ts = eng.last_stats["timeseries"]
    n = len(ts["round"])
    assert len(ts["dispatch_s"]) == len(ts["commit_s"]) \
        == len(ts["overlap_s"]) == n
    assert any(v > 0 for v in ts["overlap_s"])
    rounds = eng.last_stats["sla"]["rounds"]
    assert rounds["n"] == n
    assert rounds["overlap_s_mean"] > 0
    # serial rounds never report overlap
    eng = _engine(pipeline=False)
    eng.serve(_fresh(_reqs(4)))
    assert all(v == 0.0 for v in
               eng.last_stats["timeseries"]["overlap_s"])


# ------------------------------------------------------- deadline ordering
def test_slack_orders_preemption_victims():
    """Deadline-aware preemption: with priorities equal, the deadline-
    less request (infinite slack) yields its slot before the request
    racing a deadline — flipping the old newest-first outcome when the
    deadline request is newer."""
    eng = _engine()
    st = eng._open_session([], None)
    # two live slots: uid 0 (older, no deadline), uid 1 (newer, tight
    # deadline).  Old rule (priority, admit_seq) picks the newer uid 1;
    # slack-first must pick uid 0.
    for uid, deadline in ((0, None), (1, 10_000.0)):
        req = Request(uid=uid, prompt=[1, 2, 3], max_new_tokens=4,
                      deadline_ms=deadline)
        eng._register(st, req)
        st.live[uid] = req
        st.admit_seq[uid] = uid
    victim = eng._preempt_victim(st)
    assert victim == 0
    # without deadlines anywhere, ties fall back to the old rule exactly
    st.live[1] = dataclasses.replace(st.live[1], deadline_ms=None)
    st.has_deadlines = False
    assert eng._preempt_victim(st) == 1


def test_slack_parity_without_deadlines():
    """No request carries a deadline -> every slack is +inf and the
    slack-aware ordering must reproduce the old outputs bit-for-bit
    (guarded by the preemption-churn parity test above); here we pin the
    stats too."""
    reqs = _reqs(6, mlo=6, mhi=12)
    (ref, s_ref), (out, s_pipe) = _both(reqs, num_pages=4)
    assert out == ref
    assert ([s_ref[r.uid]["preemptions"] for r in reqs]
            == [s_pipe[r.uid]["preemptions"] for r in reqs])


# ------------------------------------------------------------- calibration
def test_calibrate_cost_model():
    cfg, model, params = _model()
    cm = calibrate(model, params, max_seq=32, repeats=1)
    assert cm.source == "measured"
    assert cm.swap_gbps > 0 and cm.decode_flops_s > 0


def test_engine_cost_model_wiring():
    eng = _engine()
    assert eng.cost_model == DEFAULT_COST_MODEL
    explicit = CostModel(1e9, 1e12, source="explicit")
    eng = _engine(cost_model=explicit)
    assert eng.cost_model is explicit
    eng = _engine(preempt_calibrate=True)
    assert eng.cost_model.source == "measured"


def test_cost_model_steers_auto_preempt():
    """preempt=auto flips between swap and requeue as the measured
    figures move: an infinitely fast link swaps, an infinitely fast
    model recomputes."""
    reqs = _reqs(6, mlo=6, mhi=12)
    swap_wins = CostModel(swap_gbps=1e15, decode_flops_s=1e3)
    eng = _engine(num_pages=4, preempt="auto", cost_model=swap_wins)
    eng.serve(_fresh(reqs))
    assert eng.last_pool_stats.swap_outs > 0
    recompute_wins = CostModel(swap_gbps=1e-3, decode_flops_s=1e15)
    eng = _engine(num_pages=4, preempt="auto", cost_model=recompute_wins)
    eng.serve(_fresh(reqs))
    assert eng.last_pool_stats.swap_outs == 0


# ------------------------------------------------------------- hypothesis
if _HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(seed=hyp_st.integers(0, 2**16),
           n=hyp_st.integers(2, 6),
           pages=hyp_st.sampled_from([16, 24, 48]),
           temperature=hyp_st.sampled_from([0.0, 0.7]))
    def test_property_pipeline_toggle_is_invisible(seed, n, pages,
                                                   temperature):
        """Random schedules (prompt/output lengths, pool pressure,
        temperature) serve bit-identically with pipeline toggled."""
        reqs = _reqs(n, seed=seed)
        (ref, _), (out, _) = _both(reqs, num_pages=pages,
                                   temperature=temperature)
        assert out == ref
