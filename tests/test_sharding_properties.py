"""Hypothesis property tests for the sharding rule engine.

System invariant: every PartitionSpec the engine emits must be *valid* for
its shape on its mesh — each dim's assigned axes divide the dim — across
arbitrary shapes, meshes, and policies.  This is the property the 512-chip
dry-run depends on (an invalid spec is a compile failure at scale).
"""

import math

import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import (
    ShardingPolicy,
    batch_pspecs,
    cache_spec,
    param_spec,
)


class FakeMesh:
    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


def _axes_size(mesh, entry):
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        return math.prod(mesh.shape[a] for a in entry)
    return mesh.shape[entry]


def assert_valid(spec: P, shape, mesh):
    assert len(spec) <= len(shape)
    seen = set()
    for dim, entry in zip(shape, tuple(spec) + (None,) * len(shape)):
        n = _axes_size(mesh, entry)
        assert dim % n == 0, (spec, shape, mesh.shape)
        if entry is not None:
            names = entry if isinstance(entry, tuple) else (entry,)
            for a in names:
                assert a not in seen, f"axis {a} used twice in {spec}"
                seen.add(a)


mesh_st = st.sampled_from([
    FakeMesh(data=16, model=16),
    FakeMesh(pod=2, data=16, model=16),
    FakeMesh(data=4, model=2),
    FakeMesh(data=1, model=1),
])

dim_st = st.sampled_from([1, 2, 3, 5, 8, 12, 16, 64, 80, 100, 127, 128,
                          256, 1024, 2048, 3072, 49155, 151936])

policy_st = st.sampled_from([
    ShardingPolicy(),
    ShardingPolicy(head_aware=True, n_heads=12, n_kv_heads=2),
    ShardingPolicy(fsdp_axis=("data", "model"), tp_axis=None),
    ShardingPolicy(fsdp_axis=("data", "model"), tp_axis=None,
                   batch_axes=("pod", "data")),
    ShardingPolicy(kv_seq_tp=True),
])

path_st = st.sampled_from([
    "embed", "lm_head", "vit_proj", "ln_f",
    "layers/attn/wq", "layers/attn/wk", "layers/attn/wv", "layers/attn/wo",
    "layers/attn/bq", "layers/mlp/w_gate", "layers/moe/w_gate",
    "layers/moe/router", "layers/tm/wr", "layers/mamba/in_proj",
    "encoder/attn/wq", "shared_attn/attn/wk",
])


@settings(max_examples=300, deadline=None)
@given(path=path_st, dims=st.lists(dim_st, min_size=1, max_size=4),
       mesh=mesh_st, policy=policy_st)
def test_param_spec_always_valid(path, dims, mesh, policy):
    shape = tuple(dims)
    spec = param_spec(path, shape, mesh, policy)
    assert_valid(spec, shape, mesh)


@settings(max_examples=300, deadline=None)
@given(name=st.sampled_from(["k", "v", "attn_k", "latent", "rope", "wkv",
                             "shift_tm", "conv", "ssm", "unknown"]),
       dims=st.lists(dim_st, min_size=2, max_size=5),
       mesh=mesh_st, policy=policy_st)
def test_cache_spec_always_valid(name, dims, mesh, policy):
    shape = tuple(dims)
    spec = cache_spec(name, shape, mesh, policy)
    assert_valid(spec, shape, mesh)


@settings(max_examples=150, deadline=None)
@given(b=dim_st, s=dim_st, mesh=mesh_st, policy=policy_st)
def test_batch_specs_always_valid(b, s, mesh, policy):
    shapes = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
              "pos": jax.ShapeDtypeStruct((b,), jnp.int32)}
    specs = batch_pspecs(shapes, mesh, policy)
    assert_valid(specs["tokens"], (b, s), mesh)
    assert_valid(specs["pos"], (b,), mesh)


def test_small_leaves_replicate():
    mesh = FakeMesh(data=16, model=16)
    for path in ("layers/ln1", "layers/attn/bq", "ln_f"):
        assert param_spec(path, (80, 4096), mesh) == P()


def test_head_aware_blocks_indivisible_heads():
    mesh = FakeMesh(data=16, model=16)
    pol = ShardingPolicy(head_aware=True, n_heads=64, n_kv_heads=8)
    # kv heads (8) don't divide model (16): no TP on the kv projections
    assert param_spec("layers/attn/wk", (80, 8192, 1024), mesh, pol) == \
        P(None, "data", None)
    # q heads (64) do divide: column-parallel wq, row-parallel wo
    assert param_spec("layers/attn/wq", (80, 8192, 8192), mesh, pol) == \
        P(None, "data", "model")
    assert param_spec("layers/attn/wo", (80, 8192, 8192), mesh, pol) == \
        P(None, "model", "data")


def test_kv_seq_tp_prefers_sequence():
    mesh = FakeMesh(data=16, model=16)
    pol = ShardingPolicy(kv_seq_tp=True)
    assert cache_spec("k", (80, 128, 32768, 8, 128), mesh, pol) == \
        P(None, "data", "model", None, None)
    # non-KV state leaves unchanged
    assert cache_spec("wkv", (32, 128, 64, 64, 64), mesh, pol) == \
        cache_spec("wkv", (32, 128, 64, 64, 64), mesh)
