"""Hypothesis property tests for the system's invariants.

Invariant 1 (the paper's core contract): the HW path and the SW path are
*semantically identical* for every primitive, every warp/tile geometry, every
member mask — they differ only in where the exchange happens.

Invariant 2: algebraic laws of the collectives (shuffle round-trips, ballot
popcount == any-count, reduce == segment fold, scan last == reduce).
"""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import repro.core.primitives as P
from repro.core import TileGroup, WarpConfig

SETTINGS = dict(max_examples=25, deadline=None)

pow2_ws = st.sampled_from([4, 8, 16, 32, 64])
small_batch = st.integers(min_value=1, max_value=3)


def _vals(draw, batch, ws, dtype=np.int32):
    data = draw(st.lists(st.integers(-1000, 1000),
                         min_size=batch * ws, max_size=batch * ws))
    return jnp.asarray(np.asarray(data, dtype=dtype).reshape(batch, ws))


@st.composite
def warp_values(draw):
    ws = draw(pow2_ws)
    batch = draw(small_batch)
    return _vals(draw, batch, ws), ws


@given(warp_values(), st.integers(0, 63))
@settings(**SETTINGS)
def test_shfl_hw_eq_sw(wv, delta):
    v, ws = wv
    d = delta % ws
    for f in (P.shfl_up, P.shfl_down):
        np.testing.assert_array_equal(
            np.asarray(f(v, d, backend="hw")), np.asarray(f(v, d, backend="sw")))
    m = delta % ws
    np.testing.assert_array_equal(
        np.asarray(P.shfl_xor(v, m, backend="hw")),
        np.asarray(P.shfl_xor(v, m, backend="sw")))


@given(warp_values())
@settings(**SETTINGS)
def test_shfl_xor_involution(wv):
    """shfl_xor(shfl_xor(v, m), m) == v — butterfly is its own inverse."""
    v, ws = wv
    for m in (1, ws // 2, ws - 1):
        for b in ("hw", "sw"):
            r = P.shfl_xor(P.shfl_xor(v, m, backend=b), m, backend=b)
            np.testing.assert_array_equal(np.asarray(r), np.asarray(v))


@given(warp_values(), st.integers(0, 2**32 - 1))
@settings(**SETTINGS)
def test_votes_hw_eq_sw_with_masks(wv, raw_mask):
    v, ws = wv
    pred = v > 0
    mask = raw_mask | 1  # lane 0 always a member (vote_uni needs >= 1 member)
    for f in (P.vote_all, P.vote_any):
        np.testing.assert_array_equal(
            np.asarray(f(pred, member_mask=mask, backend="hw")),
            np.asarray(f(pred, member_mask=mask, backend="sw")))
    np.testing.assert_array_equal(
        np.asarray(P.vote_ballot(pred, member_mask=mask, backend="hw")),
        np.asarray(P.vote_ballot(pred, member_mask=mask, backend="sw")))
    np.testing.assert_array_equal(
        np.asarray(P.vote_uni(v, member_mask=mask, backend="hw")),
        np.asarray(P.vote_uni(v, member_mask=mask, backend="sw")))


@given(warp_values())
@settings(**SETTINGS)
def test_ballot_popcount_equals_sum(wv):
    """popcount(ballot(p)) == sum(p) — ballot and reduction must agree."""
    v, ws = wv
    pred = v > 0
    ballot = np.asarray(P.vote_ballot(pred, backend="hw"))
    counts = np.asarray(pred.sum(-1))
    if ballot.ndim == 1:  # <=32 lanes: single word
        pop = np.array([bin(int(w)).count("1") for w in ballot])
    else:
        pop = np.array([sum(bin(int(w)).count("1") for w in row)
                        for row in ballot])
    np.testing.assert_array_equal(pop, counts)


@given(warp_values(), st.sampled_from(["sum", "max", "min"]))
@settings(**SETTINGS)
def test_reduce_hw_eq_sw_and_oracle(wv, op):
    v, ws = wv
    hw = np.asarray(P.warp_reduce(v, op, backend="hw"))
    sw = np.asarray(P.warp_reduce(v, op, backend="sw"))
    np.testing.assert_array_equal(hw, sw)  # ints: exact
    fn = {"sum": np.sum, "max": np.max, "min": np.min}[op]
    np.testing.assert_array_equal(
        hw, np.broadcast_to(fn(np.asarray(v), -1, keepdims=True), v.shape))


@given(warp_values())
@settings(**SETTINGS)
def test_scan_last_equals_reduce(wv):
    v, ws = wv
    for b in ("hw", "sw"):
        scan = np.asarray(P.warp_scan(v, "sum", backend=b))
        red = np.asarray(P.warp_reduce(v, "sum", backend=b))
        np.testing.assert_array_equal(scan[..., -1], red[..., -1])


@st.composite
def tiled_values(draw):
    ws = draw(st.sampled_from([8, 16, 32]))
    size = draw(st.sampled_from([s for s in (4, 8, 16) if s <= ws]))
    batch = draw(small_batch)
    return _vals(draw, batch, ws), TileGroup(size, WarpConfig(warp_size=ws))


@given(tiled_values())
@settings(**SETTINGS)
def test_tile_reduce_segment_locality(tv):
    """A tile collective must never mix values across tile boundaries."""
    v, tile = tv
    ws, size = tile.warp.warp_size, tile.size
    for b in ("hw", "sw"):
        got = np.asarray(P.tile_reduce(v, tile, "sum", backend=b))
        seg = np.asarray(v).reshape(v.shape[0], ws // size, size)
        expect = np.broadcast_to(seg.sum(-1, keepdims=True), seg.shape)
        np.testing.assert_array_equal(got, expect.reshape(v.shape))


@given(tiled_values(), st.integers(1, 7))
@settings(**SETTINGS)
def test_tile_shfl_up_down_compose(tv, delta):
    """shfl_down(shfl_up(v, d), d) restores interior lanes of each segment."""
    v, tile = tv
    d = delta % tile.size
    for b in ("hw", "sw"):
        r = P.shfl_down(P.shfl_up(v, d, tile=tile, backend=b), d,
                        tile=tile, backend=b)
        got = np.asarray(r).reshape(v.shape[0], -1, tile.size)
        want = np.asarray(v).reshape(v.shape[0], -1, tile.size)
        if d:
            np.testing.assert_array_equal(got[..., d:-d] if d < tile.size - d
                                          else got[..., 0:0],
                                          want[..., d:-d] if d < tile.size - d
                                          else want[..., 0:0])
        else:
            np.testing.assert_array_equal(got, want)
