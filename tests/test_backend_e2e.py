"""End-to-end HW/SW/Pallas backend equivalence at the model level.

The paper's deployment story: the same model runs with warp features
implemented in 'hardware' (vector/register lowering), 'software'
(PR-serialized), or as explicit Pallas kernels — users pick per the
area/performance constraint.  These tests pin the three paths to the same
function values in a real model forward/training step.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.models.config import ModelConfig
from repro.models.layers import WarpFeatureConfig
from repro.models.lm import Model
from repro.optim.optimizer import AdamWConfig
from repro.train.step import init_train_state, make_train_step

CFG = ModelConfig(name="tiny-be", family="dense", n_layers=2, d_model=128,
                  n_heads=4, n_kv_heads=2, d_ff=256, vocab=512, max_seq=64)


def _batch(s=16, b=2):
    data = SyntheticPipeline(DataConfig(vocab=CFG.vocab, seq_len=s,
                                        global_batch=b, seed=5))
    return data.batch_at(0)


def _forward(backend, warp_size=64):
    wf = WarpFeatureConfig(reduction_backend=backend, warp_size=warp_size)
    model = Model(CFG, wf=wf, compute_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    return model.forward(params, _batch())


def test_model_forward_hw_equals_sw():
    ref = _forward("hw")
    sw = _forward("sw")
    np.testing.assert_allclose(np.asarray(sw), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_model_forward_hw_equals_hw_warp():
    ref = _forward("hw")
    hw_warp = _forward("hw_warp")
    np.testing.assert_allclose(np.asarray(hw_warp), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.slow
def test_model_forward_pallas_rmsnorm_interpret():
    # the fused Pallas RMSNorm inside a full model; on CPU the kernels
    # auto-select interpret mode (kernels/common.default_interpret)
    ref = _forward("hw")
    pl_out = _forward("pallas")
    np.testing.assert_allclose(np.asarray(pl_out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_train_step_sw_backend_decreases_loss():
    wf = WarpFeatureConfig(reduction_backend="sw", warp_size=64)
    model = Model(CFG, wf=wf, compute_dtype=jnp.float32)
    opt = AdamWConfig(lr=2e-3, warmup_steps=1, total_steps=20)
    step = jax.jit(make_train_step(model, opt, vocab_chunks=2))
    state = init_train_state(model, jax.random.PRNGKey(0))
    data = SyntheticPipeline(DataConfig(vocab=CFG.vocab, seq_len=32,
                                        global_batch=4, seed=9))
    losses = []
    init_state = state
    for i in range(12):
        state, m = step(state, data.batch_at(i))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    # learning signal on a *fixed* batch (step-to-step history compares
    # different random batches, whose spread exceeds 12 steps of progress)
    from repro.train.step import make_loss_fn
    loss_fn = jax.jit(make_loss_fn(model, vocab_chunks=2))
    fixed = data.batch_at(0)
    before = float(loss_fn(init_state.params, fixed))
    after = float(loss_fn(state.params, fixed))
    assert after < before - 0.05, (before, after)


def test_hw_sw_gradients_match():
    """The two lowerings must agree up to float assoc. in the BACKWARD
    too — SW serialization cannot change what the model learns."""
    batch = _batch(s=8)

    def loss(backend):
        wf = WarpFeatureConfig(reduction_backend=backend, warp_size=64)
        model = Model(CFG, wf=wf, compute_dtype=jnp.float32)
        params = model.init(jax.random.PRNGKey(0))

        def f(p):
            logits = model.forward(p, batch)
            return jnp.mean(logits.astype(jnp.float32) ** 2)

        return jax.grad(f)(params)

    g_hw = loss("hw")
    g_sw = loss("sw")
    for a, b in zip(jax.tree.leaves(g_hw), jax.tree.leaves(g_sw)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-5)
